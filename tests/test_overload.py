"""Overload-resilience tests: the graceful-degradation controller
(hysteresis levels, shed-stale queues, rung caps, tier deferral), the
seeded lossy-link fault injector (FaultPlan schedule determinism +
FaultyTransport per-kind semantics), the loss soak (a ResumableSession
over a faulty link converges to the bit-identical stream, loopback and
TCP), and the overload soak (offered load past the drain rate sheds
deterministically, bounds queue wait, and never retraces)."""

import jax
import numpy as np
import pytest

from repro import api
from repro.core import pipeline as P
from repro.data import synthetic as SYN
from repro.runtime.fault import FaultPlan
from repro.serve import ChunkQueue, ServerConfig, StreamServer
from repro.serve.adaptive import KLadderController
from repro.serve.degrade import (
    DegradeConfig,
    DegradeController,
    LevelPolicy,
    validate_degrade,
)
from repro.wire import codec
from repro.wire.fault import FaultyTransport
from repro.wire.loadgen import LoadConfig, LoadGen
from repro.wire.server import (
    IngestServer,
    Loopback,
    ResumableSession,
    ResumeError,
    WireClient,
)

FRAME = 64
PATCH = 16
CHUNK = 8


def _ecfg(**kw):
    base = dict(
        frame_hw=(FRAME, FRAME), patch=PATCH, capacity=32,
        tau=0.10, gamma=0.015, theta=8, window=16,
    )
    base.update(kw)
    return P.EPICConfig(**base)


def _sensor_chunks(seed, n_frames=16, n_obj=4):
    scfg = SYN.StreamConfig(n_frames=n_frames, hw=(FRAME, FRAME), n_obj=n_obj)
    s, _ = SYN.generate_stream(jax.random.PRNGKey(seed), scfg)
    stream = api.SensorChunk(s.frames, s.poses, s.gazes, s.depth)
    return list(api.iter_chunks(stream, CHUNK, remainder="drop"))


def _assert_tree_bitwise(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{msg} leaf {i}"
        )


# ---------------------------------------------------------------------------
# DegradeController: validation + hysteresis state machine


class TestDegradeController:
    def _cfg(self, **kw):
        base = dict(
            enter=(0.5, 0.8), exit=(0.3, 0.6), dwell_ticks=2,
        )
        base.update(kw)
        return DegradeConfig(**base)

    def test_validation_rejects_malformed_ladders(self):
        with pytest.raises(ValueError, match="at least one level"):
            validate_degrade(DegradeConfig(levels=(), enter=(), exit=()))
        with pytest.raises(ValueError, match="lengths"):
            validate_degrade(self._cfg(enter=(0.5,)))
        with pytest.raises(ValueError, match="hysteresis"):
            validate_degrade(self._cfg(exit=(0.5, 0.6)))
        with pytest.raises(ValueError, match="strictly increasing"):
            validate_degrade(self._cfg(enter=(0.8, 0.8), exit=(0.3, 0.6)))
        with pytest.raises(ValueError, match="dwell_ticks"):
            validate_degrade(self._cfg(dwell_ticks=0))
        with pytest.raises(ValueError, match="arrival_weight"):
            validate_degrade(self._cfg(arrival_weight=-1.0))
        with pytest.raises(ValueError, match="latency_budget_s"):
            validate_degrade(self._cfg(latency_budget_s=0.0))
        with pytest.raises(ValueError, match="queue policy"):
            validate_degrade(self._cfg(levels=(
                LevelPolicy(queue_policy="newest_wins"), LevelPolicy(),
            )))
        with pytest.raises(ValueError, match="stale_after_ticks"):
            validate_degrade(self._cfg(levels=(
                LevelPolicy(stale_after_ticks=0), LevelPolicy(),
            )))
        with pytest.raises(ValueError, match=">= 0"):
            validate_degrade(self._cfg(levels=(
                LevelPolicy(rung_cap_down=-1), LevelPolicy(),
            )))

    def test_hysteresis_climbs_and_recovers_one_step_per_dwell(self):
        dg = DegradeController(self._cfg())
        assert dg.observe(0.6) == 0  # first confirmation only
        assert dg.observe(0.55) == 1  # dwell met -> one step up
        assert dg.policy == dg.cfg.levels[0]
        assert dg.observe(0.85) == 1
        assert dg.observe(0.85) == 2
        # pressure between exit[1] and enter thresholds: hold, and the
        # partial confirmation streak resets
        assert dg.observe(0.7) == 2
        assert dg.observe(0.6) == 2  # first exit confirmation
        assert dg.observe(0.6) == 1
        # 0.31 > exit[0]=0.3 interrupts the downward dwell
        assert dg.observe(0.31) == 1
        assert dg.observe(0.3) == 1
        assert dg.observe(0.3) == 0
        assert dg.policy.rung_cap_down == 0  # neutral again
        c = dg.counters()
        assert c["n_transitions"] == 4
        assert c["n_observed"] == sum(c["ticks_at_level"])

    def test_noisy_signal_cannot_flap(self):
        dg = DegradeController(self._cfg())
        for _ in range(8):  # alternating above/below enter[0]
            dg.observe(0.6)
            dg.observe(0.2)
        assert dg.level == 0
        assert dg.n_transitions == 0

    def test_arrival_and_latency_signals_raise_pressure(self):
        cfg = self._cfg(
            enter=(0.5,), exit=(0.2,), levels=(LevelPolicy(),),
            dwell_ticks=1, arrival_weight=1.0, latency_budget_s=0.1,
        )
        dg = DegradeController(cfg)
        assert dg.observe(0.0, arrival_ema=0.7) == 1
        assert dg.pressure == pytest.approx(0.7)
        dg2 = DegradeController(cfg)
        assert dg2.observe(0.0, service_s=0.09) == 1
        assert dg2.pressure == pytest.approx(0.9)
        # the default config ignores both extra signals
        dg3 = DegradeController(DegradeConfig())
        dg3.observe(0.0, arrival_ema=100.0, service_s=100.0)
        assert dg3.pressure == 0.0


# ---------------------------------------------------------------------------
# ChunkQueue tick stamps + shed_stale; KLadderController rung cap


class TestQueueTickStamps:
    def test_shed_stale_drops_only_stamped_older_entries(self):
        q = ChunkQueue(maxlen=4)
        for tick in (0, 1, None, 3):
            assert q.push(f"c{tick}", tick=tick)
        assert q.shed_stale(before_tick=2) == 2  # ticks 0 and 1
        # the unstamped entry at the head stops the shed loop
        assert q.shed_stale(before_tick=99) == 0
        assert q.n_shed == 2
        chunk, ts, tick = q.pop_full()
        assert (chunk, tick) == ("cNone", None)
        assert q.pop_full()[2] == 3

    def test_pop_entry_keeps_two_tuple_contract(self):
        q = ChunkQueue(maxlen=2)
        q.push("c", ts=1.5, tick=7)
        chunk, ts = q.pop_entry()  # strict 2-tuple unpack must work
        assert (chunk, ts) == ("c", 1.5)


class TestRungCap:
    def test_default_cap_is_top_of_ladder(self):
        ctl = KLadderController((8, 16, 32), start_k=8)
        assert ctl.rung_cap == 2
        ctl.update(overflow=1, peak_full=0)
        ctl.update(overflow=1, peak_full=0)
        assert ctl.k == 32  # uncapped growth reaches the top

    def test_cap_clamps_now_and_bounds_growth(self):
        ctl = KLadderController((8, 16, 32), start_k=32)
        ctl.set_rung_cap(1)
        assert ctl.k == 16  # clamped down immediately
        ctl.update(overflow=1, peak_full=16)  # overflow wants to grow...
        assert ctl.k == 16  # ...but the cap holds
        ctl.set_rung_cap(None)
        assert ctl.rung_cap == 2
        ctl.update(overflow=1, peak_full=16)
        assert ctl.k == 32

    def test_cap_out_of_range_raises(self):
        ctl = KLadderController((8, 16), start_k=8)
        with pytest.raises(ValueError, match="out of range"):
            ctl.set_rung_cap(2)
        with pytest.raises(ValueError, match="out of range"):
            ctl.set_rung_cap(-1)


# ---------------------------------------------------------------------------
# FaultPlan: deterministic schedule


class TestFaultPlan:
    def test_same_seed_same_schedule(self):
        mk = lambda: FaultPlan(
            seed=7, rates={"drop": 0.2, "corrupt": 0.1}
        )
        a = [mk().next_action() for _ in range(1)]  # smoke single
        p1, p2 = mk(), mk()
        s1 = [p1.next_action() for _ in range(64)]
        s2 = [p2.next_action() for _ in range(64)]
        assert s1 == s2
        assert p1.counts == p2.counts
        assert sum(p1.counts.values()) == 64
        assert p1.counts["drop"] > 0
        del a

    def test_at_pins_do_not_shift_the_tail(self):
        base = FaultPlan(seed=3, rates={"drop": 0.3})
        pinned = FaultPlan(
            seed=3, rates={"drop": 0.3}, at={5: "corrupt"}
        )
        sb = [base.next_action() for _ in range(32)]
        sp = [pinned.next_action() for _ in range(32)]
        assert sp[5] == "corrupt"
        assert sp[:5] == sb[:5]
        assert sp[6:] == sb[6:]  # one draw per index regardless

    def test_warmup_always_delivers(self):
        plan = FaultPlan(seed=0, rates={"drop": 1.0}, warmup=4)
        acts = [plan.next_action() for _ in range(8)]
        assert acts[:4] == ["deliver"] * 4
        assert acts[4:] == ["drop"] * 4

    def test_validation(self):
        with pytest.raises(ValueError, match="unknown fault"):
            FaultPlan(rates={"deliver": 0.5})
        with pytest.raises(ValueError, match="unknown fault"):
            FaultPlan(rates={"mangle": 0.5})
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            FaultPlan(rates={"drop": 1.5})
        with pytest.raises(ValueError, match="> 1"):
            FaultPlan(rates={"drop": 0.6, "dup": 0.6})
        with pytest.raises(ValueError, match="not one of"):
            FaultPlan(at={0: "mangle"})


# ---------------------------------------------------------------------------
# FaultyTransport: per-kind wire semantics (against a recording stub)


class _RecordingTransport:
    """Records every forwarded message; ACKs data frames by echoing
    their (sid, seq), ACKs everything else with zeros."""

    def __init__(self):
        self.sent = []

    def send(self, msg):
        self.sent.append(bytes(msg))
        if bytes(memoryview(msg)[:4]) == codec.DATA_MAGIC:
            _, _, _, sid, seq, *_ = codec.FRAME_HEADER.unpack_from(
                bytes(msg)[: codec.FRAME_HEADER.size]
            )
            return codec.Reply(codec.ACK, sid, seq)
        return codec.Reply(codec.ACK, 0, 0)


class TestFaultyTransport:
    def _frame(self, seq):
        chunk = _sensor_chunks(0)[0]
        return codec.encode_chunk(
            chunk, stream_id=4, seq=seq, timestamp_ns=0
        )

    def _ft(self, at):
        rec = _RecordingTransport()
        return rec, FaultyTransport(rec, FaultPlan(at=at))

    def test_control_frames_bypass_the_plan(self):
        rec, ft = self._ft(at={0: "drop"})
        ft.send(codec.encode_control(codec.OP_OPEN, 4))
        assert len(rec.sent) == 1
        assert ft.plan.n_sent == 0  # the drop pin is still unspent

    def test_drop_swallows_and_synthesizes_ack(self):
        rec, ft = self._ft(at={0: "drop"})
        r = ft.send(self._frame(5))
        assert rec.sent == []
        assert r.ok and (r.stream_id, r.seq) == (4, 5)

    def test_dup_forwards_twice_returns_one_reply(self):
        rec, ft = self._ft(at={0: "dup"})
        msg = self._frame(0)
        r = ft.send(msg)
        assert rec.sent == [msg, msg]
        assert r.ok and r.seq == 0

    def test_reorder_holds_until_next_forwarded_frame(self):
        rec, ft = self._ft(at={0: "reorder"})
        first, second = self._frame(0), self._frame(1)
        r = ft.send(first)
        assert rec.sent == [] and r.ok  # held, optimistic ACK
        ft.send(second)
        assert rec.sent == [second, first]  # late arrival after

    def test_corrupt_flips_one_payload_bit(self):
        rec, ft = self._ft(at={0: "corrupt"})
        msg = self._frame(0)
        ft.send(msg)
        (wire,) = rec.sent
        assert len(wire) == len(msg)
        assert wire[:-1] == msg[:-1] and wire[-1] == msg[-1] ^ 0x01
        with pytest.raises(codec.WireCRCError):
            codec.decode_frame(wire)

    def test_truncate_delivers_a_prefix(self):
        rec, ft = self._ft(at={0: "truncate"})
        ft.send(self._frame(0))
        (wire,) = rec.sent
        assert len(wire) == codec.DATA_HEADER_NBYTES + 1
        with pytest.raises(codec.WireFormatError):
            codec.decode_frame(wire)


# ---------------------------------------------------------------------------
# Loss soak: lossy link converges to the bit-identical stream


LADDER = (8, 16)


def _strict_server():
    srv = StreamServer(
        api.EPICCompressor(_ecfg(prefilter_k=8)),
        ServerConfig(
            capacity=2, chunk_frames=CHUNK, queue_depth=2,
            k_ladder=LADDER,
        ),
    )
    return srv, IngestServer(srv, strict_seq=True)


def _solo_state(chunks):
    solo = api.EPICCompressor(_ecfg(prefilter_k=8), k_ladder=LADDER)
    state = solo.init()
    for c in chunks:
        state, _ = solo.step(state, c)
    return state, solo.k_trajectory


class TestLossSoakLoopback:
    PINS = {2: "drop", 4: "dup", 5: "reorder", 7: "corrupt", 8: "truncate"}

    def _soak(self, chunks):
        srv, ingest = _strict_server()
        plan = FaultPlan(seed=11, at=dict(self.PINS), warmup=2)
        sess = ResumableSession(
            FaultyTransport(Loopback(ingest), plan),
            9, window=64, drain=ingest.tick,
        )
        assert sess.open().ok
        for c in chunks:
            assert sess.send_chunk(c).ok
            ingest.tick()
        while any(len(q) for q in srv._queues.values()):
            ingest.tick()
        return srv, ingest, sess, plan

    def test_lossy_run_is_bit_identical_to_lossless(self):
        chunks = _sensor_chunks(2, n_frames=80, n_obj=5)
        srv, ingest, sess, plan = self._soak(chunks)
        # every fault kind actually fired on schedule
        for kind in set(self.PINS.values()):
            assert plan.counts[kind] >= 1, kind
        # the recovery machinery did real work
        assert sess.n_retransmits >= 1
        assert sess.n_damage_retries >= 1
        assert ingest.counters()["n_seq_gaps"] >= 1
        # ...and converged to the bit-identical per-stream state
        state, ks = _solo_state(chunks)
        _assert_tree_bitwise(state, srv.state(9), "lossy soak")
        assert srv.telemetry(9).k_trajectory == ks
        # zero retraces: every dispatched variant compiled exactly once
        assert all(v == 1 for v in srv.step_cache_sizes().values())

    def test_soak_is_deterministic(self):
        chunks = _sensor_chunks(2, n_frames=80, n_obj=5)
        runs = []
        for _ in range(2):
            srv, ingest, sess, plan = self._soak(chunks)
            runs.append((
                dict(plan.counts),
                sess.n_retransmits,
                sess.n_damage_retries,
                sess.n_already_served,
                ingest.counters(),
            ))
        assert runs[0] == runs[1]


class TestLossSoakTCP:
    def test_lossy_tcp_link_converges(self):
        chunks = _sensor_chunks(6, n_frames=48)
        srv, ingest = _strict_server()
        try:
            host, port = ingest.start_tcp_in_thread()
        except (OSError, PermissionError) as e:  # pragma: no cover
            pytest.skip(f"cannot bind local TCP socket: {e}")
        try:
            plan = FaultPlan(
                seed=4, at={2: "drop", 3: "corrupt"}, warmup=2
            )
            with WireClient(host, port) as client:
                sess = ResumableSession(
                    FaultyTransport(client, plan),
                    13, window=64, drain=ingest.tick,
                )
                assert sess.open().ok
                for c in chunks:
                    assert sess.send_chunk(c).ok
                    ingest.tick()
                while any(len(q) for q in srv._queues.values()):
                    ingest.tick()
            assert plan.counts["drop"] == 1
            assert plan.counts["corrupt"] == 1
            assert sess.n_retransmits >= 1
            state, ks = _solo_state(chunks)
            _assert_tree_bitwise(state, srv.state(13), "tcp lossy soak")
            assert srv.telemetry(13).k_trajectory == ks
        finally:
            ingest.stop()


# ---------------------------------------------------------------------------
# Overload soak: deterministic shed, bounded wait, zero retraces


OVERLOAD_DEGRADE = DegradeConfig(
    enter=(0.3, 0.6), exit=(0.1, 0.25), dwell_ticks=1,
)


class TestOverloadSoak:
    def _run(self, mult, seed=5):
        srv = StreamServer(
            api.EPICCompressor(_ecfg(prefilter_k=8)),
            ServerConfig(
                capacity=3, chunk_frames=CHUNK, queue_depth=2,
                k_ladder=LADDER, eviction="lru",
            ),
        )
        srv.degrade = DegradeController(OVERLOAD_DEGRADE)
        ingest = IngestServer(srv)
        cfg = LoadConfig(
            seed=seed, ticks=10, arrival_rate=1.0,
            session_len_mu=1.5, session_len_sigma=0.4,
            submit_per_tick=mult,
        )
        summary = LoadGen(cfg, _sensor_chunks(0, n_frames=16), ingest).run()
        return srv, ingest, summary

    def test_overload_sheds_deterministically(self):
        a = self._run(4)
        b = self._run(4)
        for (srv, ingest, summary) in (a, b):
            # degraded levels held and shed work freshest-first (the
            # drop_oldest flip; staleness shed needs starved queues —
            # exercised in TestTierDeferral)
            assert sum(srv.degrade.counters()["ticks_at_level"][1:]) > 0
            assert srv.server_counters()["n_dropped"] > 0
        # rtt carries wall-clock percentiles; only its sample count is
        # deterministic (PR 10) — the rest of the summary must match
        # exactly
        rtt_a, rtt_b = a[2].pop("rtt"), b[2].pop("rtt")
        assert rtt_a["count"] == rtt_b["count"] > 0
        assert a[2] == b[2]  # loadgen event log + counters
        assert a[0].degrade.counters() == b[0].degrade.counters()
        assert a[0].server_counters() == b[0].server_counters()

    def test_wait_bounded_and_zero_retraces_and_recovery(self):
        srv, ingest, summary = self._run(4)
        # staleness deadline (level 1: 4 ticks) + queue-depth slack
        assert srv.max_queue_wait_ticks <= 4 + srv.cfg.queue_depth
        # degradation never compiled a new program shape
        assert all(v == 1 for v in srv.step_cache_sizes().values())
        # the burst passed: pressure drains and the level walks home
        for _ in range(8):
            ingest.tick()
        assert srv.degrade.level == 0
        # level 0 restored the configured queue policy
        assert all(
            q.policy == srv.cfg.queue_policy
            for q in srv._queues.values()
        )
        c = srv.server_counters()
        assert c["n_shed_stale"] == srv.degrade.n_shed
        assert c["degrade_level"] == 0

    def test_light_load_never_degrades(self):
        srv, ingest, summary = self._run(1)
        assert srv.degrade.counters()["ticks_at_level"][0] > 0
        assert srv.degrade.counters()["n_shed"] == 0


class TestTierDeferral:
    def test_level_defers_cold_tier_dispatch(self):
        srv = StreamServer(
            api.EPICCompressor(_ecfg(prefilter_k=8)),
            ServerConfig(
                capacity=4, chunk_frames=CHUNK, queue_depth=2,
                tiers=(2, 2),
            ),
        )
        # one level that defers the coldest tier and sheds anything
        # older than 2 ticks; any backlog at all trips it in one tick
        srv.degrade = DegradeController(DegradeConfig(
            enter=(0.01,), exit=(0.005,),
            levels=(LevelPolicy(defer_tiers=1, stale_after_ticks=2),),
            dwell_ticks=1,
        ))
        # tiered admission is coldest-first: X, Y land in tier 1,
        # Z in tier 0 once the cold tier fills
        for sid in ("X", "Y", "Z"):
            srv.admit(sid)
        assert srv._locate("X")[0] == 1
        assert srv._locate("Z")[0] == 0
        chunk = _sensor_chunks(0)[0]
        for sid in ("X", "Y", "Z"):
            assert srv.submit(sid, chunk)
        stepped = srv.tick()
        # the hot tier served; the deferred cold tier kept its backlog
        assert stepped == ["Z"]
        assert len(srv._queues["X"]) == 1 and len(srv._queues["Y"]) == 1
        assert srv.degrade.level == 1
        # the starved cold-tier chunks (stamped tick 0) cross the
        # 2-tick staleness deadline and are shed, not served
        for _ in range(3):
            srv.tick()
        assert srv.degrade.n_shed == 2
        assert len(srv._queues["X"]) == 0 and len(srv._queues["Y"]) == 0
        assert srv.server_counters()["n_shed_stale"] == 2
        # with the backlog gone, pressure falls and the level walks home
        srv.tick()
        assert srv.degrade.level == 0
