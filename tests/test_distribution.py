"""Distribution-layer tests: sharding rules, chunked kernels, EP MoE,
HLO collective parsing, token packing. CPU-only; multi-device pieces run
in a subprocess with forced host devices (the main process has already
locked jax to one device)."""

from __future__ import annotations

import json
import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.launch import sharding as S
from repro.launch.hloparse import analyze_collectives
from repro.models import build_model
from repro.models import layers as L


# Minimal env for subprocess tests. JAX_PLATFORMS must be forwarded:
# without it jax probes for accelerator plugins at import, which hangs
# on CI machines with no device.
_SUB_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
for _k in ("JAX_PLATFORMS", "HOME"):
    if _k in os.environ:
        _SUB_ENV[_k] = os.environ[_k]


def _mesh(shape=(16, 16), axes=("data", "model")):
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


class TestParamSpecs:
    def test_dense_tp_rules(self):
        cfg = get_config("olmo-1b")
        model = build_model(cfg)
        specs = S.param_specs(cfg, model.param_spec(), _mesh())
        P = jax.sharding.PartitionSpec
        assert specs["embed"]["table"] == P("model", None)
        assert specs["layers"]["attn"]["wq"]["w"] == P(None, None, "model")
        assert specs["layers"]["attn"]["wo"]["w"] == P(None, "model", None)
        assert specs["layers"]["mlp"]["down"]["w"] == P(None, "model", None)

    def test_kv_heads_not_divisible_replicates_kv(self):
        cfg = get_config("qwen2.5-3b")  # kv=2 < 16
        model = build_model(cfg)
        specs = S.param_specs(cfg, model.param_spec(), _mesh())
        P = jax.sharding.PartitionSpec
        assert specs["layers"]["attn"]["wk"]["w"] == P()
        assert specs["layers"]["attn"]["wq"]["w"] == P(None, None, "model")

    def test_expert_sharding_dp_model(self):
        cfg = get_config("deepseek-v3-671b")
        model = build_model(cfg)
        specs = S.param_specs(cfg, model.param_spec(), _mesh())
        got = specs["moe_layers"]["moe"]["gate_w"]
        assert got == jax.sharding.PartitionSpec(
            None, ("data", "model"), None, None
        )

    def test_dp_strategy_replicates_everything(self):
        cfg = get_config("olmo-1b").replace(shard_strategy="dp")
        model = build_model(cfg)
        specs = S.param_specs(cfg, model.param_spec(), _mesh())
        assert all(
            s == jax.sharding.PartitionSpec()
            for s in jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec)
            )
        )

    def test_zero1_adds_data_axis(self):
        cfg = get_config("olmo-1b")
        model = build_model(cfg)
        ospecs = S.opt_specs(cfg, model.param_spec(), _mesh())
        P = jax.sharding.PartitionSpec
        # mlp down (L, F, D): param (None, "model", None) + data on D
        assert ospecs.mu["layers"]["mlp"]["down"]["w"] == P(
            None, "model", "data"
        )
        assert ospecs.step == P()

    def test_zero1_never_duplicates_axis(self):
        cfg = get_config("deepseek-v3-671b")
        model = build_model(cfg)
        ospecs = S.opt_specs(cfg, model.param_spec(), _mesh())
        for spec in jax.tree.leaves(
            ospecs.mu,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        ):
            seen = []
            for entry in spec:
                if entry is None:
                    continue
                seen += list(entry) if isinstance(entry, tuple) else [entry]
            assert len(seen) == len(set(seen)), spec

    def test_cache_seq_shard_when_heads_dont_divide(self):
        cfg = get_config("qwen2.5-3b")
        model = build_model(cfg)
        sshape = model.serve_spec(128, 32768)
        specs = S.serve_specs(cfg, sshape, _mesh(), 128)
        P = jax.sharding.PartitionSpec
        assert specs["k"] == P(None, ("data",), None, "model", None)

    def test_batch_specs_divisibility(self):
        cfg = get_config("olmo-1b")
        from repro.configs.base import ShapeSpec

        sp = S.batch_specs(cfg, ShapeSpec("x", "train", 4096, 256), _mesh())
        assert sp["tokens"] == jax.sharding.PartitionSpec(("data",), None)
        # batch=1 (long_500k) -> replicated
        sp = S.batch_specs(cfg, ShapeSpec("x", "decode", 1024, 1), _mesh())
        assert sp["tokens"] == jax.sharding.PartitionSpec(None, None)


# ---------------------------------------------------------------------------
# Chunked attention == reference softmax attention
# ---------------------------------------------------------------------------


def _ref_attention(q, k, v, causal, window):
    s = q.shape[2]
    sk = k.shape[2]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(q.shape[-1])
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), bool)
    if causal:
        mask = kpos <= qpos
    if window:
        mask = mask & (kpos > qpos - window)
    probs = jax.nn.softmax(jnp.where(mask, logits, -1e30), -1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


@pytest.mark.parametrize("s,qc,kc", [(128, 64, 32), (96, 64, 64), (130, 64, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_attention_chunked_matches_ref(s, qc, kc, causal):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q, k, v = (jax.random.normal(kk, (2, 3, s, 16)) for kk in ks)
    ref = _ref_attention(q, k, v, causal, None)
    out = L.attention_chunked(q, k, v, causal=causal, q_chunk=qc, k_chunk=kc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_attention_chunked_dv_neq_dk():
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, 2, 64, 24))
    k = jax.random.normal(k2, (2, 2, 64, 24))
    v = jax.random.normal(k3, (2, 2, 64, 40))  # MLA-style wider/narrower V
    ref = _ref_attention(q, k, v, True, None)
    out = L.attention_chunked(q, k, v, causal=True, q_chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([32, 48, 64]),
    window=st.sampled_from([None, 16]),
    seed=st.integers(0, 2**30),
)
def test_attention_chunked_property(s, window, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q, k, v = (jax.random.normal(kk, (1, 2, s, 8)) for kk in ks)
    ref = _ref_attention(q, k, v, True, window)
    out = L.attention_chunked(q, k, v, causal=True, window=window,
                              q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Chunked linear-attention scans == sequential refs
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(t=st.sampled_from([31, 64, 96]), seed=st.integers(0, 2**30))
def test_rwkv6_chunked_matches_ref(t, seed):
    from repro.kernels.rwkv6_scan.ops import rwkv6_scan

    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    B, H, K = 2, 2, 8
    r, k, v = (jax.random.normal(kk, (B, H, t, K)) for kk in ks[:3])
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, H, t, K)) * 2)
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    o1, s1 = rwkv6_scan(r, k, v, w_log, u, backend="ref")
    o2, s2 = rwkv6_scan(r, k, v, w_log, u, backend="chunked", chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(t=st.sampled_from([33, 64]), seed=st.integers(0, 2**30))
def test_mamba2_chunked_matches_ref(t, seed):
    from repro.kernels.mamba2_ssd.ops import mamba2_ssd

    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    B, H, N, P_ = 2, 2, 4, 8
    x = jax.random.normal(ks[0], (B, H, t, P_))
    a_log = -jnp.exp(jax.random.normal(ks[1], (B, H, t)))
    bm = jax.random.normal(ks[2], (B, t, N))
    cm = jax.random.normal(ks[3], (B, t, N))
    y1, s1 = mamba2_ssd(x, a_log, bm, cm, backend="ref")
    y2, s2 = mamba2_ssd(x, a_log, bm, cm, backend="chunked", chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# EP MoE == sort MoE (subprocess: needs >1 device)
# ---------------------------------------------------------------------------


def test_ep_moe_matches_sort_subprocess():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs import get_smoke_config
        from repro.models import moe as MOE
        key = jax.random.PRNGKey(0)
        out = {}
        base = get_smoke_config("deepseek-v2-lite-16b").replace(
            moe_capacity_factor=8.0)
        mesh = jax.make_mesh((2,4), ("data","model"))
        B,S,D = 8, 16, base.d_model
        x = jax.random.normal(jax.random.fold_in(key,2), (B,S,D))*0.3
        for name, cfg in (
            ("tp", base.replace(ep_axes="model", shard_strategy="tp")),
            ("fsdp", base.replace(ep_axes="dp_model", shard_strategy="fsdp")),
        ):
            p = MOE.init_moe(jax.random.fold_in(key,1), cfg)
            y_ref, _ = MOE.moe_ffn_sort(p, x, cfg)
            with mesh:
                y_ep, _ = jax.jit(lambda p,x: MOE.moe_ffn_ep(p,x,cfg))(p, x)
                g1 = jax.jit(jax.grad(
                    lambda p,x: MOE.moe_ffn_ep(p,x,cfg)[0].sum()))(p,x)
            g2 = jax.grad(lambda p,x: MOE.moe_ffn_sort(p,x,cfg)[0].sum())(p,x)
            gerr = max(float(jnp.abs(a-b).max())
                       for a,b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
            out[name] = [float(jnp.abs(y_ref-y_ep).max()), gerr]
        print(json.dumps(out))
    """)
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=500, env=_SUB_ENV,
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    for name, (yerr, gerr) in res.items():
        assert yerr < 1e-5, (name, yerr)
        assert gerr < 1e-4, (name, gerr)


def test_ep_moe_int8_dispatch_subprocess():
    """int8-quantized all-to-all dispatch stays within fp8-regime error."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, json
        from repro.configs import get_smoke_config
        from repro.models import moe as MOE
        key = jax.random.PRNGKey(0)
        base = get_smoke_config("deepseek-v2-lite-16b").replace(
            moe_capacity_factor=8.0, ep_axes="model")
        cfgq = base.replace(moe_a2a_quant=True)
        p = MOE.init_moe(jax.random.fold_in(key,1), base)
        x = jax.random.normal(jax.random.fold_in(key,2),
                              (8, 16, base.d_model))*0.3
        y_ref, _ = MOE.moe_ffn_sort(p, x, base)
        mesh = jax.make_mesh((2,4), ("data","model"))
        with mesh:
            yq, _ = jax.jit(lambda p,x: MOE.moe_ffn_ep(p,x,cfgq))(p, x)
            gq = jax.jit(jax.grad(
                lambda p,x: MOE.moe_ffn_ep(p,x,cfgq)[0].sum()))(p,x)
        g2 = jax.grad(lambda p,x: MOE.moe_ffn_sort(p,x,base)[0].sum())(p,x)
        rel = float(jnp.abs(y_ref-yq).max()/jnp.abs(y_ref).max())
        grel = max(float(jnp.abs(a-b).max()/(jnp.abs(a).max()+1e-9))
                   for a,b in zip(jax.tree.leaves(gq), jax.tree.leaves(g2)))
        print(json.dumps([rel, grel]))
    """)
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=500, env=_SUB_ENV,
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    rel, grel = json.loads(r.stdout.strip().splitlines()[-1])
    assert rel < 0.03, rel
    assert grel < 0.1, grel


def test_ep_moe_falls_back_without_mesh():
    from repro.configs import get_smoke_config
    from repro.models import moe as MOE

    cfg = get_smoke_config("deepseek-v2-lite-16b").replace(moe_impl="ep")
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = MOE.moe_ffn(p, x, cfg)  # no ambient mesh -> sort fallback
    assert y.shape == x.shape


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------


HLO_SAMPLE = """HloModule test, num_partitions=4

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ar = f32[8,8]{1,0} all-reduce(%x), replica_groups=[2,2]<=[4], to_apply=%add
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %w = (s32[], f32[8,8]) while(%t), condition=%c, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %ag = f32[16,8]{1,0} all-gather(%y), replica_groups=[1,4]<=[4], dimensions={0}
}
"""


def test_hloparse_trip_count_multiplies():
    r = analyze_collectives(HLO_SAMPLE)
    # all-reduce inside the loop counted 10x: 10 * 8*8*4 bytes
    assert r["by_op"]["all-reduce"] == 10 * 8 * 8 * 4
    assert r["by_op"]["all-gather"] == 16 * 8 * 4
    assert r["counts"]["all-reduce"] == 10
    # ring factors: AR group=2 -> 2*(1/2)=1.0x; AG group=4 -> 3/4
    assert r["wire_bytes"] == pytest.approx(
        10 * 256 * 1.0 + 512 * 0.75
    )


def test_hloparse_upcast_detection():
    txt = HLO_SAMPLE.replace("all-reduce(%x)", "all-reduce(%convert_fusion)")
    r = analyze_collectives(txt)
    assert r["tpu_wire_bytes"] < r["wire_bytes"]


# ---------------------------------------------------------------------------
# Packing subsample
# ---------------------------------------------------------------------------


def test_pack_subsamples_uniformly_in_time():
    from repro.core import packing

    n, p = 100, 16
    rgb = jnp.zeros((n, p, p, 3))
    t = jnp.arange(n, dtype=jnp.float32)
    origin = jnp.zeros((n, 2))
    valid = jnp.ones((n,), bool)
    ts = packing.pack(rgb, t, origin, valid, 10, t_max=100.0)
    t_feat = np.asarray(ts.tokens[:, packing.THUMB * packing.THUMB * 3]) * 100
    assert t_feat[0] == 0 and t_feat[-1] == 99  # full span, no truncation
    assert np.all(np.diff(t_feat) > 5)  # roughly uniform
