"""Fused Pallas TSRC backend parity (interpret mode).

The ``fused`` backend must (a) appear in the reproject-match registry
and serve the standard (diff, coverage, bbox) contract through the
untouched dispatcher, (b) agree with the ``ref`` oracle and bitwise
with the ``pallas`` kernel, (c) produce in-kernel threshold/update-mask
rows consistent with composing the same thresholds outside the kernel,
and (d) drive the full EPIC pipeline to the same results as the
composed backends."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import geometry as geo
from repro.core import pipeline as P
from repro.core import tsrc as tsrc_mod
from repro.data import synthetic as SYN
from repro.kernels.reproject_match.fused import reproject_match_fused
from repro.kernels.reproject_match.kernel import reproject_match_pallas
from repro.kernels.reproject_match.ops import reproject_match
from repro.kernels.reproject_match.ref import reproject_match_ref


def _inputs(key, n, p, hw):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    rgb = jax.random.uniform(k1, (n, p, p, 3))
    depth = jax.random.uniform(k2, (n, p, p), minval=1.0, maxval=4.0)
    oy = jax.random.randint(k3, (n,), 0, hw - p).astype(jnp.float32)
    ox = jax.random.randint(k4, (n,), 0, hw - p).astype(jnp.float32)
    origin = jnp.stack([oy, ox], -1)
    angles = jax.random.normal(k5, (n, 3)) * 0.05
    trans = jax.random.normal(k1, (n, 3)) * 0.1
    t_rel = geo.pose_from_rt(geo.rotation_xyz(angles), trans)
    frame = jax.random.uniform(k2, (hw, hw, 3))
    intr = geo.Intrinsics.create(0.8 * hw, hw / 2.0, hw / 2.0)
    return rgb, depth, origin, t_rel, frame, intr


class TestRegistry:
    def test_fused_registered(self):
        assert "fused" in api.available_backends()

    def test_dispatches_through_untouched_op(self):
        """backend="fused" flows through ops.reproject_match purely via
        the registry — same contract as ref/pallas."""
        args = _inputs(jax.random.PRNGKey(3), 4, 16, 64)
        d, c, b = reproject_match(*args, window=32, backend="fused")
        d0, c0, b0 = reproject_match(*args, window=32, backend="ref")
        assert d.shape == d0.shape == (4,)
        np.testing.assert_allclose(d, d0, atol=1e-5)
        np.testing.assert_allclose(c, c0, atol=1e-5)

    def test_capability_attribute(self):
        fn = api.get_backend("fused")
        assert callable(getattr(fn, "fused_match"))
        assert getattr(api.get_backend("ref"), "fused_match", None) is None


class TestOpParity:
    @pytest.mark.parametrize(
        "n,p,hw,window", [(4, 16, 128, 32), (7, 16, 128, 64), (1, 8, 64, 16)]
    )
    def test_matches_ref(self, n, p, hw, window):
        args = _inputs(jax.random.PRNGKey(n * 7 + p), n, p, hw)
        d0, c0, b0 = reproject_match_ref(*args, window)
        d, c, b, _, _ = reproject_match_fused(
            *args, window=window, interpret=True
        )
        np.testing.assert_allclose(d0, d, atol=1e-5)
        np.testing.assert_allclose(c0, c, atol=1e-5)
        np.testing.assert_allclose(b0, b, atol=1e-3)

    def test_bitwise_identical_to_pallas(self):
        """Both kernels share _entry_scores: scores must agree bit for
        bit, not just within tolerance."""
        args = _inputs(jax.random.PRNGKey(11), 6, 16, 128)
        d1, c1, b1 = reproject_match_pallas(*args, window=32, interpret=True)
        d2, c2, b2, _, _ = reproject_match_fused(
            *args, window=32, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))

    def test_match_rows_consistent_with_composition(self):
        """In-kernel thresholds + patch-grid overlap == composing the
        same thresholds outside the kernel from its own outputs."""
        tau, o_min, c_min = 0.08, 0.5, 0.6
        p = 16
        args = _inputs(jax.random.PRNGKey(5), 6, p, 128)
        frame = args[4]
        d, c, b, pair_ok, overlap_ok = reproject_match_fused(
            *args, window=32, tau=tau, o_min=o_min, c_min=c_min,
            interpret=True,
        )
        _, origins = tsrc_mod.extract_patches(frame, p)
        overlap = geo.bbox_overlap_fraction(
            b[:, None, :], origins[None, :, :], p
        )
        ref_ovok = overlap >= o_min
        ref_pair = ((d <= tau) & (c >= c_min))[:, None] & ref_ovok
        np.testing.assert_array_equal(
            np.asarray(overlap_ok), np.asarray(ref_ovok)
        )
        np.testing.assert_array_equal(
            np.asarray(pair_ok), np.asarray(ref_pair)
        )

    def test_shapes(self):
        n, p, hw = 3, 16, 64
        args = _inputs(jax.random.PRNGKey(1), n, p, hw)
        m = (hw // p) * (hw // p)
        d, c, b, pair_ok, overlap_ok = reproject_match_fused(
            *args, window=32, interpret=True
        )
        assert d.shape == (n,) and c.shape == (n,) and b.shape == (n, 4)
        assert pair_ok.shape == (n, m) and pair_ok.dtype == jnp.bool_
        assert overlap_ok.shape == (n, m)


class TestPipelineParity:
    """EPIC end-to-end on the fused backend vs the composed backends."""

    def _run(self, backend, chunk):
        cfg = P.EPICConfig(
            frame_hw=(64, 64), patch=16, capacity=16,
            tau=0.10, gamma=0.015, theta=8, window=16, backend=backend,
        )
        comp = api.get_compressor("epic")(cfg)
        return jax.jit(comp.step)(comp.init(), chunk)

    @pytest.fixture(scope="class")
    def chunk(self):
        scfg = SYN.StreamConfig(n_frames=20, hw=(64, 64), n_obj=4)
        s, _ = SYN.generate_stream(jax.random.PRNGKey(1), scfg)
        return api.SensorChunk(s.frames, s.poses, s.gazes, s.depth)

    def test_fused_pipeline_matches_ref(self, chunk):
        sf, tf = self._run("fused", chunk)
        sr, tr = self._run("ref", chunk)
        for a, b in zip(jax.tree.leaves((sf, tf)), jax.tree.leaves((sr, tr))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fused_pipeline_matches_pallas(self, chunk):
        sf, tf = self._run("fused", chunk)
        sp, tp = self._run("pallas", chunk)
        for a, b in zip(jax.tree.leaves((sf, tf)), jax.tree.leaves((sp, tp))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
