"""Sparse TRD (two-phase bbox-prefiltered reproject-match) test suite.

Pins the tentpole contract of ``kernels/reproject_match/sparse.py`` +
``TSRCConfig.prefilter_k``:

* the prefilter's candidate selection (all passing entries chosen when
  they fit, newest-first truncation + overflow counter when they don't);
* **bit parity with the dense path whenever at most K entries pass** —
  at the ``tsrc_step`` level, under jit, through the chunked
  ``EPICCompressor`` session, and on every registered backend;
* conservative truncation semantics when more than K entries pass
  (extra insertions, never false matches);
* fail-fast ``prefilter_k`` validation on ``TSRCConfig``/``EPICConfig``
  construction and ``_replace``.

The ``prefilter_k=0`` (dense) default is pinned separately by the
pre-refactor stage-graph goldens in ``tests/test_stages.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import dc_buffer as dcb
from repro.core import geometry as geo
from repro.core import pipeline as P
from repro.core import tsrc as tsrc_mod
from repro.data import synthetic as SYN
from repro.kernels.reproject_match import sparse as sparse_mod

FRAME = 64
PATCH = 16
N_PATCHES = (FRAME // PATCH) ** 2


def _intr(hw=FRAME):
    return geo.Intrinsics.create(0.8 * hw, hw / 2.0, hw / 2.0)


def _tree_equal_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Phase 1: prefilter unit behaviour
# ---------------------------------------------------------------------------


class TestBboxPrefilter:
    def _prefilter(self, origins_e, t, valid, salient, k, o_min=0.5):
        n = t.shape[0]
        corner_d = jnp.full((n, 4), 3.0)
        t_rel = jnp.broadcast_to(jnp.eye(4), (n, 4, 4))
        _, patch_origins = tsrc_mod.extract_patches(
            jnp.zeros((FRAME, FRAME, 3)), PATCH
        )
        return sparse_mod.bbox_prefilter(
            origins_e, corner_d, t_rel, t, valid, patch_origins, salient,
            _intr(), PATCH, o_min=o_min, k=k,
        )

    def test_all_passing_selected_when_under_k(self):
        """Identity warp: each entry sits exactly on its own patch, so
        every valid entry over a salient patch passes and is selected."""
        origins_e = jnp.array([[0.0, 0.0], [0.0, 16.0], [16.0, 0.0]])
        t = jnp.array([2.0, 0.0, 1.0])
        valid = jnp.array([True, True, True])
        salient = jnp.ones((N_PATCHES,), bool)
        pre = self._prefilter(origins_e, t, valid, salient, k=8)
        assert int(pre.n_pass) == 3
        assert int(pre.n_full) == 3
        assert int(pre.n_overflow) == 0
        assert set(np.asarray(pre.cand_idx[pre.cand_real]).tolist()) == {
            0, 1, 2,
        }

    def test_invalid_and_nonsalient_do_not_pass(self):
        origins_e = jnp.array([[0.0, 0.0], [0.0, 16.0], [16.0, 16.0]])
        t = jnp.array([0.0, 1.0, 2.0])
        valid = jnp.array([True, False, True])  # entry 1 is an empty slot
        # Only the patch under entry 0 is salient.
        salient = jnp.zeros((N_PATCHES,), bool).at[0].set(True)
        pre = self._prefilter(origins_e, t, valid, salient, k=3)
        np.testing.assert_array_equal(
            np.asarray(pre.passes), [True, False, False]
        )
        assert int(pre.n_full) == 1

    def test_truncation_keeps_newest(self):
        origins_e = jnp.zeros((4, 2))  # all on the same (salient) patch
        t = jnp.array([3.0, 9.0, 1.0, 7.0])
        valid = jnp.ones((4,), bool)
        salient = jnp.ones((N_PATCHES,), bool)
        pre = self._prefilter(origins_e, t, valid, salient, k=2)
        assert int(pre.n_pass) == 4
        assert int(pre.n_full) == 2
        assert int(pre.n_overflow) == 2
        # The two newest (t=9 at idx 1, t=7 at idx 3) are the candidates.
        assert set(np.asarray(pre.cand_idx).tolist()) == {1, 3}


# ---------------------------------------------------------------------------
# Sparse == dense bit parity when at most K entries pass
# ---------------------------------------------------------------------------


class TestSparseDenseParity:
    CAP = 32

    def _frames(self, seed=0):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        f1 = jax.random.uniform(k1, (FRAME, FRAME, 3))
        f2 = f1.at[:, FRAME // 2 :].set(
            jax.random.uniform(k2, (FRAME, FRAME // 2, 3))
        )
        return f1, f2

    def _run_steps(self, prefilter_k, backend="ref", seed=0, jit=False):
        buf_cfg = dcb.DCBufferConfig(capacity=self.CAP, patch=PATCH)
        cfg = tsrc_mod.TSRCConfig(
            window=32, backend=backend, prefilter_k=prefilter_k
        )
        sal = jnp.ones((N_PATCHES,), bool)
        common = (
            jnp.full((FRAME, FRAME), 3.0), sal, jnp.ones((N_PATCHES,)),
            jnp.eye(4),
        )
        step = tsrc_mod.tsrc_step
        if jit:
            step = jax.jit(step, static_argnames=("buf_cfg", "cfg"))
        f1, f2 = self._frames(seed)
        buf = dcb.init(buf_cfg)
        buf, _ = step(
            buf, buf_cfg, cfg, f1, *common, jnp.float32(0), _intr()
        )
        buf, stats = step(
            buf, buf_cfg, cfg, f2, *common, jnp.float32(1), _intr()
        )
        return buf, stats

    @pytest.mark.parametrize("jit", [False, True])
    def test_k_at_capacity_bitwise_equals_dense(self, jit):
        """prefilter_k >= capacity can never truncate: the whole step —
        buffer AND every stat counter — must equal dense bit for bit."""
        dense = self._run_steps(0, jit=jit)
        sparse = self._run_steps(self.CAP, jit=jit)
        _tree_equal_bitwise(dense, sparse)
        assert int(sparse[1].n_prefilter_overflow) == 0

    def test_k_above_observed_passing_bitwise_equals_dense(self):
        """A K strictly between the passing count and capacity is still
        exact — dense n_full_checks IS the passing count, so use it."""
        dense_buf, dense_stats = self._run_steps(0)
        n_pass = int(dense_stats.n_full_checks)
        assert 0 < n_pass < self.CAP
        sparse = self._run_steps(n_pass)  # tightest exact K
        _tree_equal_bitwise((dense_buf, dense_stats), sparse)

    @pytest.mark.parametrize("backend", ["pallas", "pallas_tiled", "fused"])
    def test_parity_on_every_backend(self, backend):
        """The two-phase path composes with every registered backend
        (for fused, the prefilter takes precedence over fused_match)."""
        dense = self._run_steps(0, backend="ref")
        sparse = self._run_steps(self.CAP, backend=backend)
        _tree_equal_bitwise(dense, sparse)

    def test_truncation_is_conservative(self):
        """With K=1, at most one entry can match; every other salient
        patch is (re-)inserted — extra insertions, never false matches."""
        dense_buf, dense_stats = self._run_steps(0)
        trunc_buf, trunc_stats = self._run_steps(1)
        assert int(trunc_stats.n_prefilter_overflow) == (
            int(dense_stats.n_full_checks) - 1
        )
        assert int(trunc_stats.n_full_checks) == 1
        assert int(trunc_stats.n_matched) <= int(dense_stats.n_matched)
        assert int(trunc_stats.n_inserted) >= int(dense_stats.n_inserted)
        assert int(trunc_stats.n_matched) + int(trunc_stats.n_inserted) == (
            int(trunc_stats.n_salient)
        )


# ---------------------------------------------------------------------------
# End-to-end: chunked EPICCompressor session parity
# ---------------------------------------------------------------------------


class TestSessionParity:
    def _cfg(self, prefilter_k):
        return P.EPICConfig(
            frame_hw=(FRAME, FRAME), patch=PATCH, capacity=48,
            tau=0.10, gamma=0.015, theta=8, window=16,
            prefilter_k=prefilter_k,
        )

    @pytest.fixture(scope="class")
    def stream(self):
        scfg = SYN.StreamConfig(n_frames=24, hw=(FRAME, FRAME), n_obj=4)
        s, _ = SYN.generate_stream(jax.random.PRNGKey(2), scfg)
        return api.SensorChunk(s.frames, s.poses, s.gazes, s.depth)

    def test_sparse_session_bitwise_equals_dense(self, stream):
        """Full pipeline (bypass gate + depth + saliency + TSRC) under
        jit: prefilter_k = capacity never truncates -> bit parity,
        including the stats trajectory and zero overflow everywhere."""
        dense = api.EPICCompressor(self._cfg(0))
        sparse = api.EPICCompressor(self._cfg(48))
        ds, dt = jax.jit(dense.step)(dense.init(), stream)
        ss, st = jax.jit(sparse.step)(sparse.init(), stream)
        _tree_equal_bitwise((ds, dt), (ss, st))
        assert int(jnp.sum(st.n_prefilter_overflow)) == 0

    def test_chunked_ingest_bitwise_equals_one_shot(self, stream):
        """The session contract survives the sparse path: arbitrary
        chunk splits are bit-identical to one big ingest."""
        comp = api.EPICCompressor(self._cfg(48))
        one_state, _ = jax.jit(comp.step)(comp.init(), stream)
        step = jax.jit(comp.step)
        state = comp.init()
        for lo, hi in ((0, 8), (8, 16), (16, 24)):
            state, _ = step(
                state,
                api.SensorChunk(
                    stream.frames[lo:hi], stream.poses[lo:hi],
                    stream.gazes[lo:hi],
                    stream.depth[lo:hi],
                ),
            )
        _tree_equal_bitwise(one_state, state)

    def test_truncating_session_runs_and_reports_overflow(self, stream):
        comp = api.EPICCompressor(self._cfg(2))
        state, stats = jax.jit(comp.step)(comp.init(), stream)
        assert int(jnp.sum(stats.n_prefilter_overflow)) > 0
        # Per-frame candidate count is capped by K on processed frames.
        assert int(jnp.max(stats.n_full_checks)) <= 2
        assert int(dcb.count_valid(state.buf)) > 0


# ---------------------------------------------------------------------------
# Fail-fast validation (mirrors the backend-typo contract)
# ---------------------------------------------------------------------------


class TestPrefilterKValidation:
    def test_negative_rejected(self):
        with pytest.raises(ValueError, match="prefilter_k"):
            tsrc_mod.TSRCConfig(prefilter_k=-1)
        with pytest.raises(ValueError, match="prefilter_k"):
            P.EPICConfig(prefilter_k=-3)

    def test_non_int_rejected(self):
        with pytest.raises(TypeError, match="prefilter_k"):
            tsrc_mod.TSRCConfig(prefilter_k=1.5)
        with pytest.raises(TypeError, match="prefilter_k"):
            P.EPICConfig(prefilter_k="16")

    def test_replace_also_validates(self):
        with pytest.raises(ValueError, match="prefilter_k"):
            tsrc_mod.TSRCConfig()._replace(prefilter_k=-2)
        with pytest.raises(ValueError, match="prefilter_k"):
            P.EPICConfig()._replace(prefilter_k=-2)
        assert P.EPICConfig()._replace(prefilter_k=16).prefilter_k == 16

    def test_zero_is_dense_default(self):
        assert tsrc_mod.TSRCConfig().prefilter_k == 0
        assert P.EPICConfig().prefilter_k == 0
