"""Ingest-frontier tests (`repro.wire`): codec round-trip properties
(zero-copy, dtype/shape/optional-depth sweep), corrupt/truncated/
wrong-version rejection, loopback ingest -> StreamServer bitwise parity
with in-process sessions (state + k_trajectory), trace record/replay
bitwise parity, seeded loadgen determinism, queue timestamp/policy
semantics, latency histogram math, and the TCP socket path."""

import math
import os
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import pipeline as P
from repro.data import synthetic as SYN
from repro.serve import ChunkQueue, ServerConfig, StreamServer
from repro.wire import codec, trace
from repro.wire.latency import LatencyHistogram, LatencyRecorder
from repro.wire.loadgen import LoadConfig, LoadGen
from repro.wire.server import IngestServer, Loopback, WireClient

from tests._hypothesis_compat import given, settings, strategies as st

FRAME = 64
PATCH = 16
CHUNK = 8


def _ecfg(**kw):
    base = dict(
        frame_hw=(FRAME, FRAME), patch=PATCH, capacity=32,
        tau=0.10, gamma=0.015, theta=8, window=16,
    )
    base.update(kw)
    return P.EPICConfig(**base)


def _sensor_chunks(seed, n_frames=16, n_obj=4):
    scfg = SYN.StreamConfig(n_frames=n_frames, hw=(FRAME, FRAME), n_obj=n_obj)
    s, _ = SYN.generate_stream(jax.random.PRNGKey(seed), scfg)
    stream = api.SensorChunk(s.frames, s.poses, s.gazes, s.depth)
    return list(api.iter_chunks(stream, CHUNK, remainder="drop"))


def _rand_chunk(rng, t, h, w, dtype, with_depth):
    def arr(shape):
        a = rng.standard_normal(shape)
        if np.issubdtype(np.dtype(dtype), np.integer):
            return (a * 100).astype(dtype)
        return a.astype(dtype)

    return api.SensorChunk(
        arr((t, h, w, 3)),
        arr((t, 4, 4)),
        arr((t, 2)),
        arr((t, h, w)) if with_depth else None,
    )


def _assert_tree_bitwise(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{msg} leaf {i}"
        )


# ---------------------------------------------------------------------------
# Codec: round-trip + rejection


class TestCodec:
    @settings(max_examples=25, deadline=None)
    @given(
        t=st.integers(1, 6),
        h=st.integers(1, 12),
        w=st.integers(1, 12),
        dtype=st.sampled_from(["float32", "float64", "uint8", "int32",
                               "float16", "int64"]),
        with_depth=st.booleans(),
        sid=st.integers(0, 2**63),
        seq=st.integers(0, 2**31),
    )
    def test_roundtrip_property(self, t, h, w, dtype, with_depth, sid, seq):
        rng = np.random.default_rng(t * 1000 + h * 10 + w)
        chunk = _rand_chunk(rng, t, h, w, dtype, with_depth)
        buf = codec.encode_chunk(
            chunk, stream_id=sid, seq=seq, timestamp_ns=17
        )
        frame = codec.decode_frame(buf)
        assert frame.stream_id == sid
        assert frame.seq == seq
        assert frame.timestamp_ns == 17
        assert (frame.chunk.depth is None) == (not with_depth)
        for a, b in zip(chunk, frame.chunk):
            if a is None:
                assert b is None
            else:
                np.testing.assert_array_equal(np.asarray(a), b)
                assert b.dtype == np.dtype(dtype)

    def test_decode_is_zero_copy(self):
        rng = np.random.default_rng(0)
        chunk = _rand_chunk(rng, 4, 8, 8, "float32", True)
        buf = codec.encode_chunk(chunk, stream_id=1, seq=0, timestamp_ns=0)
        frame = codec.decode_frame(buf)
        raw = np.frombuffer(buf, np.uint8)
        for field in frame.chunk:
            assert np.shares_memory(field, raw)

    def test_jax_arrays_encode_and_roundtrip_bitwise(self):
        chunk = _sensor_chunks(0)[0]  # jax arrays
        buf = codec.encode_chunk(chunk, stream_id=5, seq=1, timestamp_ns=2)
        back = codec.decode_frame(buf).chunk
        _assert_tree_bitwise(
            [np.asarray(x) for x in chunk if x is not None],
            [np.asarray(x) for x in back if x is not None],
        )

    def test_frame_nbytes_frames_the_stream(self):
        rng = np.random.default_rng(1)
        chunk = _rand_chunk(rng, 3, 5, 7, "float32", False)
        buf = codec.encode_chunk(chunk, stream_id=1, seq=0, timestamp_ns=0)
        assert codec.frame_nbytes(buf) == len(buf)
        assert codec.frame_nbytes(buf[: codec.FRAME_HEADER.size]) == len(buf)

    def test_rejects_truncated(self):
        rng = np.random.default_rng(2)
        buf = codec.encode_chunk(
            _rand_chunk(rng, 2, 4, 4, "float32", True),
            stream_id=1, seq=0, timestamp_ns=0,
        )
        for cut in (0, 3, codec.FRAME_HEADER.size - 1,
                    codec.DATA_HEADER_NBYTES - 1, len(buf) - 1):
            with pytest.raises(codec.WireFormatError):
                codec.decode_frame(buf[:cut])

    def test_rejects_corrupt_payload_crc(self):
        rng = np.random.default_rng(3)
        buf = bytearray(codec.encode_chunk(
            _rand_chunk(rng, 2, 4, 4, "float32", False),
            stream_id=1, seq=0, timestamp_ns=0,
        ))
        buf[-1] ^= 0x01
        with pytest.raises(codec.WireCRCError):
            codec.decode_frame(bytes(buf))
        # opt-out decodes (trusted transport), bit flip and all
        frame = codec.decode_frame(bytes(buf), verify_crc=False)
        assert frame.chunk.frames.shape == (2, 4, 4, 3)

    def test_rejects_wrong_magic_and_version(self):
        rng = np.random.default_rng(4)
        good = codec.encode_chunk(
            _rand_chunk(rng, 2, 4, 4, "float32", False),
            stream_id=1, seq=0, timestamp_ns=0,
        )
        bad_magic = b"XXXX" + good[4:]
        with pytest.raises(codec.WireFormatError, match="magic"):
            codec.decode_frame(bad_magic)
        bad_version = good[:4] + b"\x63\x00" + good[6:]
        with pytest.raises(codec.WireFormatError, match="version"):
            codec.decode_frame(bad_version)

    def test_rejects_bad_dtype_code_and_size_mismatch(self):
        rng = np.random.default_rng(5)
        good = bytearray(codec.encode_chunk(
            _rand_chunk(rng, 2, 4, 4, "float32", False),
            stream_id=1, seq=0, timestamp_ns=0,
        ))
        bad = bytearray(good)
        bad[codec.FRAME_HEADER.size] = 250  # frames slot dtype code
        with pytest.raises(codec.WireFormatError, match="dtype"):
            codec.decode_frame(bytes(bad))
        # inflate a dim so the field table overruns the payload
        bad = bytearray(good)
        dim_off = codec.FRAME_HEADER.size + 2  # first dim of frames
        bad[dim_off:dim_off + 4] = (1 << 20).to_bytes(4, "little")
        with pytest.raises(codec.WireFormatError):
            codec.decode_frame(bytes(bad))

    def test_decode_validates_cross_field_shapes(self):
        # A frame whose table claims 3 pose rows for 2 video frames
        # must be rejected by SensorChunk validation, not fail deep in
        # the scan later.
        rng = np.random.default_rng(6)
        frames = rng.standard_normal((2, 4, 4, 3)).astype(np.float32)
        poses = rng.standard_normal((3, 4, 4)).astype(np.float32)
        gazes = rng.standard_normal((2, 2)).astype(np.float32)
        payload = (frames.tobytes() + poses.tobytes() + gazes.tobytes())
        header = codec.FRAME_HEADER.pack(
            codec.DATA_MAGIC, codec.WIRE_VERSION, 0, 1, 0, 0,
            zlib.crc32(payload), len(payload),
        )
        table = b"".join(
            codec.FIELD_SLOT.pack(9, arr.ndim, *arr.shape,
                                  *([0] * (6 - arr.ndim)))
            for arr in (frames, poses, gazes)
        ) + codec.FIELD_SLOT.pack(0, 0, 0, 0, 0, 0, 0, 0)
        with pytest.raises(ValueError, match="leading axis"):
            codec.decode_frame(header + table + payload)

    def test_control_and_reply_roundtrip(self):
        ctl = codec.decode_control(codec.encode_control(codec.OP_OPEN, 77))
        assert ctl == codec.ControlFrame(codec.OP_OPEN, 77)
        assert ctl.op_name == "open"
        rep = codec.decode_reply(
            codec.encode_reply(codec.NACK_POOL_FULL, 77, 3)
        )
        assert (rep.status, rep.stream_id, rep.seq) == (
            codec.NACK_POOL_FULL, 77, 3
        )
        assert not rep.ok and rep.status_name == "pool_full"
        kind, frame = codec.decode_message(
            codec.encode_control(codec.OP_CLOSE, 8)
        )
        assert kind == "control" and frame.op == codec.OP_CLOSE
        with pytest.raises(codec.WireFormatError):
            codec.decode_message(b"JUNKJUNKJUNK")


# ---------------------------------------------------------------------------
# Satellites: iter_chunks remainder, SensorChunk validation, ChunkQueue


class TestChunkingSatellites:
    def _stream(self, n=10):
        return api.SensorChunk(
            jnp.arange(n * 4 * 4 * 3, dtype=jnp.float32).reshape(n, 4, 4, 3),
            jnp.tile(jnp.eye(4)[None], (n, 1, 1)),
            jnp.zeros((n, 2)),
            jnp.ones((n, 4, 4)),
        )

    def test_iter_chunks_remainder_modes(self):
        s = self._stream(10)
        assert [c.n_frames for c in api.iter_chunks(s, 4)] == [4, 4, 2]
        assert [
            c.n_frames
            for c in api.iter_chunks(s, 4, remainder="drop")
        ] == [4, 4]
        padded = list(api.iter_chunks(s, 4, remainder="pad"))
        assert [c.n_frames for c in padded] == [4, 4, 4]
        # pad repeats the final frame across every field
        tail = padded[-1]
        for field in tail:
            np.testing.assert_array_equal(
                np.asarray(field[-1]), np.asarray(field[1])
            )
        # the real frames of the padded tail are untouched
        np.testing.assert_array_equal(
            np.asarray(tail.frames[:2]), np.asarray(s.frames[8:10])
        )

    def test_iter_chunks_exact_multiple_identical_across_modes(self):
        s = self._stream(8)
        for mode in ("keep", "drop", "pad"):
            out = list(api.iter_chunks(s, 4, remainder=mode))
            assert [c.n_frames for c in out] == [4, 4]

    def test_iter_chunks_rejects_unknown_mode(self):
        with pytest.raises(ValueError, match="remainder"):
            list(api.iter_chunks(self._stream(8), 4, remainder="wrap"))

    def test_sensor_chunk_validation(self):
        s = self._stream(8)
        assert s.validate() is s
        bad_t = api.SensorChunk(s.frames, s.poses[:5], s.gazes, s.depth)
        with pytest.raises(ValueError, match="leading axis"):
            bad_t.validate()
        with pytest.raises(ValueError, match="leading axis"):
            bad_t.slice(0, 4)
        bad_hw = api.SensorChunk(
            s.frames, s.poses, s.gazes, s.depth[:, :2, :]
        )
        with pytest.raises(ValueError, match="depth"):
            bad_hw.validate()

    def test_chunk_queue_timestamps_and_policies(self):
        clock_now = [0.0]
        q = ChunkQueue(2, clock=lambda: clock_now[0])
        q.push("a")
        clock_now[0] = 1.5
        q.push("b")
        assert not q.push("c")  # refuse-newest default
        assert q.n_overflow == 1 and q.n_dropped == 0
        chunk, ts = q.pop_entry()
        assert (chunk, ts) == ("a", 0.0)
        assert q.pop() == "b"  # legacy signature intact

        q2 = ChunkQueue(2, policy="drop_oldest", clock=lambda: 0.0)
        assert q2.push("a") and q2.push("b") and q2.push("c")
        assert q2.n_dropped == 1 and q2.n_overflow == 0
        assert [q2.pop(), q2.pop()] == ["b", "c"]
        with pytest.raises(ValueError, match="policy"):
            ChunkQueue(2, policy="refuse_oldest")
        with pytest.raises(ValueError, match="policy"):
            StreamServer(
                api.EPICCompressor(_ecfg()),
                ServerConfig(queue_policy="nope"),
            )


# ---------------------------------------------------------------------------
# Latency histogram math


class TestLatency:
    def test_percentiles_bracket_samples(self):
        h = LatencyHistogram()
        for ms in range(1, 101):  # 1..100 ms uniform
            h.record(ms * 1e-3)
        s = h.summary()
        assert s["count"] == 100
        assert 40 <= s["p50_ms"] <= 62
        assert 85 <= s["p95_ms"] <= 100
        assert 94 <= s["p99_ms"] <= 100
        assert s["max_ms"] == 100.0
        assert h.percentile(1.0) <= 100.0 * 1e-3 + 1e-9

    def test_empty_and_extremes(self):
        h = LatencyHistogram()
        # empty percentiles are nan (defined, propagating); summaries
        # render them as None to stay JSON-safe
        assert math.isnan(h.percentile(0.5))
        assert h.summary()["p99_ms"] is None
        h.record(0.0)  # below the 1 µs floor -> underflow bucket
        h.record(1e9)  # absurd -> overflow bucket, max preserved
        assert h.n == 2
        assert h.max_s == 1e9

    def test_merge_matches_combined(self):
        a, b, c = LatencyHistogram(), LatencyHistogram(), LatencyHistogram()
        rng = np.random.default_rng(0)
        for _ in range(200):
            x = float(rng.lognormal(-4, 1))
            a.record(x) if rng.random() < 0.5 else b.record(x)
            c.record(x)
        a.merge(b)
        assert a.n == c.n
        assert a.counts == c.counts
        assert math.isclose(a.percentile(0.99), c.percentile(0.99))

    def test_recorder_splits_queue_and_service(self):
        r = LatencyRecorder()
        r.observe(0.0, 0.3, 1.0)
        r.observe(0.0, 0.1, 0.2)
        s = r.summary()
        assert s["total"]["count"] == 2
        assert s["queue_wait"]["max_ms"] == pytest.approx(300.0, rel=0.1)
        assert s["service"]["max_ms"] == pytest.approx(700.0, rel=0.1)


# ---------------------------------------------------------------------------
# Ingest server: loopback parity with in-process sessions


class TestLoopbackIngest:
    def _wire_server(self, capacity=2, k_ladder=None, **kw):
        srv = StreamServer(
            api.EPICCompressor(_ecfg(prefilter_k=8 if k_ladder else 0)),
            ServerConfig(
                capacity=capacity, chunk_frames=CHUNK, queue_depth=2,
                k_ladder=k_ladder, **kw,
            ),
        )
        ingest = IngestServer(srv)
        return srv, ingest, Loopback(ingest)

    def test_open_submit_close_protocol(self):
        srv, ingest, loop = self._wire_server()
        assert loop.send(codec.encode_control(codec.OP_OPEN, 1)).ok
        assert not loop.send(
            codec.encode_control(codec.OP_OPEN, 1)
        ).ok  # duplicate
        chunk = _sensor_chunks(0)[0]
        msg = codec.encode_chunk(chunk, stream_id=1, seq=0, timestamp_ns=0)
        assert loop.send(msg).ok
        unknown = codec.encode_chunk(
            chunk, stream_id=9, seq=0, timestamp_ns=0
        )
        assert loop.send(unknown).status_name == "unknown_stream"
        assert loop.send(b"garbage").status_name == "bad_frame"
        # close drains the queued chunk, then evicts
        assert loop.send(codec.encode_control(codec.OP_CLOSE, 1)).ok
        assert srv.live_sessions == []
        assert srv.frames_served == CHUNK
        c = ingest.counters()
        assert (c["n_opened"], c["n_closed"], c["n_frames_in"]) == (1, 1, 1)

    def test_backpressure_and_pool_full_nacks(self):
        srv, ingest, loop = self._wire_server(capacity=1)
        assert loop.send(codec.encode_control(codec.OP_OPEN, 1)).ok
        assert loop.send(
            codec.encode_control(codec.OP_OPEN, 2)
        ).status_name == "pool_full"
        chunk = _sensor_chunks(0)[0]
        for seq in range(2):
            assert loop.send(codec.encode_chunk(
                chunk, stream_id=1, seq=seq, timestamp_ns=0
            )).ok
        r = loop.send(codec.encode_chunk(
            chunk, stream_id=1, seq=2, timestamp_ns=0
        ))
        assert r.status_name == "backpressure" and r.seq == 2
        assert ingest.nacks == {"pool_full": 1, "backpressure": 1}
        assert srv.n_backpressure == 1

    def test_out_of_order_and_duplicate_seq_nacked(self):
        srv, ingest, loop = self._wire_server()
        assert loop.send(codec.encode_control(codec.OP_OPEN, 1)).ok
        chunk = _sensor_chunks(0)[0]

        def send(seq):
            return loop.send(codec.encode_chunk(
                chunk, stream_id=1, seq=seq, timestamp_ns=0
            ))

        assert send(0).ok
        # a duplicate of an accepted seq is refused, not double-served
        r = send(0)
        assert r.status_name == "out_of_order" and r.seq == 0
        srv.tick()
        # a regressed seq after progress is refused too
        assert send(5).ok
        srv.tick()
        assert send(3).status_name == "out_of_order"
        # gaps forward are fine (producers may drop frames)
        assert send(9).ok
        c = ingest.counters()
        assert c["n_out_of_order"] == 2
        assert c["nacks"]["out_of_order"] == 2
        assert c["n_frames_in"] == 3
        assert srv.frames_served == 2 * CHUNK  # dup/regressed never served

    def test_backpressure_retry_of_same_seq_still_acks(self):
        """`_seq_seen` only advances on successful submit: a producer
        retrying the seq that was NACKed with backpressure must ACK
        once the queue drains (the loadgen relies on this)."""
        srv, ingest, loop = self._wire_server(capacity=1)
        assert loop.send(codec.encode_control(codec.OP_OPEN, 1)).ok
        chunk = _sensor_chunks(0)[0]
        for seq in range(2):
            assert loop.send(codec.encode_chunk(
                chunk, stream_id=1, seq=seq, timestamp_ns=0
            )).ok
        retry = codec.encode_chunk(chunk, stream_id=1, seq=2, timestamp_ns=0)
        assert loop.send(retry).status_name == "backpressure"
        srv.tick()  # drains one queued chunk
        assert loop.send(retry).ok
        assert ingest.counters()["n_out_of_order"] == 0

    def test_loopback_parity_fixed_k(self):
        chunks = {sid: _sensor_chunks(sid, n_frames=16) for sid in (1, 2)}
        srv, ingest, loop = self._wire_server(capacity=2)
        for sid in chunks:
            assert loop.send(codec.encode_control(codec.OP_OPEN, sid)).ok
        for seq in range(2):
            for sid in chunks:
                assert loop.send(codec.encode_chunk(
                    chunks[sid][seq], stream_id=sid, seq=seq,
                    timestamp_ns=seq,
                )).ok
            ingest.tick()
        for sid in chunks:
            comp = api.EPICCompressor(_ecfg())
            step = jax.jit(comp.step)
            state = comp.init()
            for c in chunks[sid]:
                state, _ = step(state, c)
            _assert_tree_bitwise(
                state, srv.state(sid), f"stream {sid}"
            )

    def test_loopback_parity_adaptive_k_trajectory(self):
        ladder = (8, 16, 32)
        chunks = _sensor_chunks(3, n_frames=24, n_obj=5)
        srv, ingest, loop = self._wire_server(capacity=2, k_ladder=ladder)
        assert loop.send(codec.encode_control(codec.OP_OPEN, 7)).ok
        for seq, c in enumerate(chunks):
            assert loop.send(codec.encode_chunk(
                c, stream_id=7, seq=seq, timestamp_ns=seq
            )).ok
            ingest.tick()
        solo = api.EPICCompressor(
            _ecfg(prefilter_k=8), k_ladder=ladder
        )
        state = solo.init()
        for c in chunks:
            state, _ = solo.step(state, c)
        _assert_tree_bitwise(state, srv.state(7), "adaptive state")
        assert solo.k_trajectory == srv.telemetry(7).k_trajectory

    def test_tick_prunes_server_side_evictions(self):
        srv, ingest, loop = self._wire_server(
            capacity=2, eviction="idle", idle_frames=CHUNK
        )
        assert loop.send(codec.encode_control(codec.OP_OPEN, 1)).ok
        ingest.tick()  # idle >= CHUNK frames -> evicted by policy
        assert srv.live_sessions == []
        chunk = _sensor_chunks(0)[0]
        r = loop.send(codec.encode_chunk(
            chunk, stream_id=1, seq=0, timestamp_ns=0
        ))
        assert r.status_name == "unknown_stream"

    def test_latency_recorder_attaches(self):
        srv, ingest, loop = self._wire_server()
        srv.latency = LatencyRecorder()
        assert loop.send(codec.encode_control(codec.OP_OPEN, 1)).ok
        chunk = _sensor_chunks(0)[0]
        for seq in range(2):
            loop.send(codec.encode_chunk(
                chunk, stream_id=1, seq=seq, timestamp_ns=0
            ))
            ingest.tick()
        s = srv.latency.summary()
        assert s["total"]["count"] == 2
        assert s["total"]["p99_ms"] > 0
        # total = queue_wait + service, histogram-bucket tolerance
        assert s["total"]["max_ms"] >= s["service"]["max_ms"]


# ---------------------------------------------------------------------------
# Trace record/playback


class TestTrace:
    def test_record_replay_bitwise_state_parity(self, tmp_path):
        chunks = _sensor_chunks(5, n_frames=16)
        path = os.path.join(tmp_path, "session.wtrace")
        n = trace.record_session(
            chunks, path, stream_id=11, chunk_period_ns=1000,
            open_close=False,
        )
        assert n == len(chunks)

        srv = StreamServer(
            api.EPICCompressor(_ecfg()),
            ServerConfig(capacity=2, chunk_frames=CHUNK, queue_depth=2),
        )
        ingest = IngestServer(srv)
        loop = Loopback(ingest)
        assert loop.send(codec.encode_control(codec.OP_OPEN, 11)).ok
        replies = []
        trace.replay(path, loop.send, on_reply=replies.append)
        assert all(r.ok for r in replies)
        while srv.live_sessions and any(
            len(srv._queues[s]) for s in srv.live_sessions
        ):
            ingest.tick()

        comp = api.EPICCompressor(_ecfg())
        step = jax.jit(comp.step)
        state = comp.init()
        for c in chunks:
            state, _ = step(state, c)
        _assert_tree_bitwise(state, srv.state(11), "trace replay")

    def test_trace_roundtrips_messages_bitwise(self, tmp_path):
        chunks = _sensor_chunks(6, n_frames=16)
        msgs = [codec.encode_control(codec.OP_OPEN, 3)] + [
            codec.encode_chunk(c, stream_id=3, seq=i, timestamp_ns=i * 10)
            for i, c in enumerate(chunks)
        ]
        path = os.path.join(tmp_path, "t.wtrace")
        with trace.TraceWriter(path) as w:
            for i, m in enumerate(msgs):
                w.append(m, timestamp_ns=i * 1000)
        recs = trace.TraceReader(path).records()
        assert [r.timestamp_ns for r in recs] == [
            i * 1000 for i in range(len(msgs))
        ]
        for rec, msg in zip(recs, msgs):
            assert bytes(rec.message) == msg
        # decoded payloads are views of the reader's buffer (no copy)
        frame = codec.decode_frame(recs[1].message)
        assert frame.chunk.frames.base is not None

    def test_realtime_replay_paces_by_timestamps(self, tmp_path):
        path = os.path.join(tmp_path, "p.wtrace")
        with trace.TraceWriter(path) as w:
            for i in range(3):
                w.append(
                    codec.encode_control(codec.OP_OPEN, i),
                    timestamp_ns=i * 1_000_000_000,
                )
        sleeps = []
        sent = []
        trace.replay(
            path, lambda m: sent.append(bytes(m)),
            realtime=True, speed=10.0, sleep=sleeps.append,
        )
        assert len(sent) == 3
        # 1 s gaps at 10x; the injected sleep doesn't advance the wall
        # clock, so the lags accumulate: ~0.1 s then ~0.2 s.
        assert len(sleeps) == 2
        assert sleeps[0] == pytest.approx(0.1, abs=0.02)
        assert sleeps[1] == pytest.approx(0.2, abs=0.02)

    def test_reader_rejects_garbage_and_truncation(self, tmp_path):
        bad = os.path.join(tmp_path, "bad.wtrace")
        with open(bad, "wb") as f:
            f.write(b"NOTATRACE123")
        with pytest.raises(codec.WireFormatError):
            trace.TraceReader(bad)
        trunc = os.path.join(tmp_path, "trunc.wtrace")
        with trace.TraceWriter(trunc) as w:
            w.append(codec.encode_control(codec.OP_OPEN, 1))
        with open(trunc, "rb") as f:
            data = f.read()
        with open(trunc, "wb") as f:
            f.write(data[:-3])
        with pytest.raises(codec.WireFormatError, match="truncated"):
            trace.TraceReader(trunc).records()


# ---------------------------------------------------------------------------
# Load generator determinism


class TestLoadGen:
    def _run(self, seed=3):
        srv = StreamServer(
            api.EPICCompressor(_ecfg()),
            ServerConfig(capacity=2, chunk_frames=CHUNK, queue_depth=1),
        )
        srv.latency = LatencyRecorder()
        ingest = IngestServer(srv)
        cfg = LoadConfig(
            seed=seed, ticks=8, arrival_rate=1.0,
            session_len_mu=1.0, session_len_sigma=0.5,
            burst_factor=2.0, burst_every=4, submit_per_tick=1,
        )
        bank = _sensor_chunks(0, n_frames=16)
        summary = LoadGen(cfg, bank, ingest).run()
        return summary, srv

    def test_seeded_run_is_deterministic(self):
        s1, srv1 = self._run()
        s2, srv2 = self._run()
        # client-side RTT percentiles are wall-clock (their *count* is
        # deterministic, the timings are not): compare them apart from
        # the seeded-deterministic remainder
        rtt1, rtt2 = s1.pop("rtt"), s2.pop("rtt")
        assert rtt1["count"] == rtt2["count"] > 0
        assert s1 == s2
        # the latency sample count is part of the deterministic shape
        assert (
            srv1.latency.summary()["total"]["count"]
            == srv2.latency.summary()["total"]["count"]
        )
        assert s1["n_frames_acked"] > 0
        assert s1["n_sessions"] > 0

    def test_different_seed_changes_schedule(self):
        s1, _ = self._run(seed=3)
        s2, _ = self._run(seed=4)
        assert s1["event_log_sha"] != s2["event_log_sha"]

    def test_burst_exercises_backpressure(self):
        # queue_depth=1 + 2x burst sends must produce backpressure NACKs
        s, _ = self._run()
        assert s["nacks"].get("backpressure", 0) > 0

    def test_validation(self):
        srv = StreamServer(
            api.EPICCompressor(_ecfg()),
            ServerConfig(capacity=2, chunk_frames=CHUNK),
        )
        ingest = IngestServer(srv)
        with pytest.raises(ValueError, match="bank"):
            LoadGen(LoadConfig(), [], ingest)
        with pytest.raises(ValueError, match="burst_factor"):
            LoadGen(
                LoadConfig(burst_factor=0.5),
                _sensor_chunks(0), ingest,
            )


# ---------------------------------------------------------------------------
# Socket transport (TCP loopback interface)


class TestSocketTransport:
    def test_tcp_roundtrip_and_state_parity(self):
        srv = StreamServer(
            api.EPICCompressor(_ecfg()),
            ServerConfig(capacity=2, chunk_frames=CHUNK, queue_depth=2),
        )
        ingest = IngestServer(srv)
        try:
            host, port = ingest.start_tcp_in_thread()
        except (OSError, PermissionError) as e:  # pragma: no cover
            pytest.skip(f"cannot bind local TCP socket: {e}")
        try:
            chunks = _sensor_chunks(8, n_frames=16)
            with WireClient(host, port) as client:
                assert client.send(
                    codec.encode_control(codec.OP_OPEN, 21)
                ).ok
                for seq, c in enumerate(chunks):
                    r = client.send(codec.encode_chunk(
                        c, stream_id=21, seq=seq, timestamp_ns=seq
                    ))
                    assert r.ok and r.seq == seq
                    ingest.tick()
            comp = api.EPICCompressor(_ecfg())
            step = jax.jit(comp.step)
            state = comp.init()
            for c in chunks:
                state, _ = step(state, c)
            _assert_tree_bitwise(state, srv.state(21), "tcp ingest")
            assert ingest.counters()["n_frames_in"] == len(chunks)
        finally:
            ingest.stop()


# ---------------------------------------------------------------------------
# Reconnect/resume: RESUME handshake, seq gaps, windowed replay


from repro.wire.server import ResumableSession, ResumeError  # noqa: E402


class _Drop(ConnectionError):
    pass


class _DroppingTransport:
    """Loopback that drops the connection on scheduled sends:
    ``after`` seqs are delivered first (the ACK is lost — exercises
    duplicate suppression); ``before`` seqs are lost entirely (the
    frame must be replayed)."""

    def __init__(self, loop, *, before=(), after=()):
        self.loop = loop
        self.before = set(before)
        self.after = set(after)

    def _seq(self, msg):
        kind, frame = codec.decode_message(msg)
        return frame.seq if kind == "data" else None

    def send(self, msg):
        seq = self._seq(msg)
        if seq in self.before:
            self.before.discard(seq)
            raise _Drop(f"dropped before delivering seq {seq}")
        reply = self.loop.send(msg)
        if seq in self.after:
            self.after.discard(seq)
            raise _Drop(f"dropped after delivering seq {seq}")
        return reply


class _StubTransport:
    def __init__(self, replies):
        self.replies = list(replies)
        self.sent = []

    def send(self, msg):
        self.sent.append(msg)
        return self.replies.pop(0)


class TestResume:
    def _wire_server(self, **kw):
        srv = StreamServer(
            api.EPICCompressor(_ecfg()),
            ServerConfig(capacity=2, chunk_frames=CHUNK, queue_depth=2),
        )
        ingest = IngestServer(srv, **kw)
        return srv, ingest, Loopback(ingest)

    def test_resume_codec_roundtrip(self):
        msg = codec.encode_resume(9, 41)
        ctl = codec.decode_control(msg)
        assert ctl.op == codec.OP_RESUME
        assert ctl.op_name == "resume"
        assert (ctl.stream_id, ctl.seq) == (9, 42)  # wire carries +1
        fresh = codec.decode_control(codec.encode_resume(9, -1))
        assert fresh.seq == 0
        with pytest.raises(codec.WireFormatError, match="encode_resume"):
            codec.encode_control(codec.OP_RESUME, 9)
        with pytest.raises(codec.WireFormatError, match=">= -1"):
            codec.encode_resume(9, -2)
        with pytest.raises(codec.WireFormatError, match="truncated"):
            codec.decode_control(msg[: codec.CONTROL.size])
        kind, ctl2 = codec.decode_message(msg)
        assert kind == "control" and ctl2 == ctl

    def test_resume_handshake_and_dup_suppression(self):
        srv, ingest, loop = self._wire_server()
        chunk = _sensor_chunks(0)[0]
        assert loop.send(codec.encode_control(codec.OP_OPEN, 5)).ok
        for seq in range(3):
            assert loop.send(codec.encode_chunk(
                chunk, stream_id=5, seq=seq, timestamp_ns=0,
            )).ok
            ingest.tick()
        served = ingest.counters()["n_frames_in"]
        # client lost ACKs for 1 and 2: RESUME says resume from seq 2
        r = loop.send(codec.encode_resume(5, 0))
        assert r.ok and r.seq == 3  # server already has through seq 2
        for seq in (1, 2):  # window replay overlaps the server cursor
            r = loop.send(codec.encode_chunk(
                chunk, stream_id=5, seq=seq, timestamp_ns=0,
            ))
            assert r.ok  # suppressed, not out_of_order
        c = ingest.counters()
        assert c["n_resumed"] == 1
        assert c["n_dup_suppressed"] == 2
        assert c["n_frames_in"] == served  # nothing double-served
        # beyond the resume cursor a regressed seq is still refused
        r = loop.send(codec.encode_chunk(
            chunk, stream_id=5, seq=4, timestamp_ns=0,
        ))
        assert r.ok
        r = loop.send(codec.encode_chunk(
            chunk, stream_id=5, seq=3, timestamp_ns=0,
        ))
        assert r.status_name == "out_of_order"

    def test_resume_unknown_stream_nacked(self):
        _, _, loop = self._wire_server()
        r = loop.send(codec.encode_resume(404, 7))
        assert r.status_name == "unknown_stream"

    def test_resume_adopts_cursor_for_restored_slot(self):
        """A slot live in the StreamServer but unknown to this ingest
        frontier (restored from a checkpoint without wire metadata)
        adopts the client's claimed cursor."""
        srv, ingest, loop = self._wire_server()
        srv.admit(8)  # admitted out-of-band, no wire OPEN
        chunk = _sensor_chunks(1)[0]
        r = loop.send(codec.encode_resume(8, 4))
        assert r.ok and r.seq == 5
        assert ingest._seq_seen[8] == 4
        r = loop.send(codec.encode_chunk(
            chunk, stream_id=8, seq=5, timestamp_ns=0,
        ))
        assert r.ok
        assert ingest.counters()["n_seq_gaps"] == 0

    def test_seq_gaps_counted_in_lax_mode(self):
        srv, ingest, loop = self._wire_server()
        chunk = _sensor_chunks(0)[0]
        assert loop.send(codec.encode_control(codec.OP_OPEN, 3)).ok
        assert loop.send(codec.encode_chunk(
            chunk, stream_id=3, seq=2, timestamp_ns=0,  # 0,1 lost
        )).ok
        ingest.tick()
        assert loop.send(codec.encode_chunk(
            chunk, stream_id=3, seq=6, timestamp_ns=0,  # 3,4,5 lost
        )).ok
        c = ingest.counters()
        assert c["n_seq_gaps"] == 5
        assert c["seq_gaps_by_stream"] == {3: 5}
        assert c["nacks"] == {}  # lax: counted, never refused

    def test_strict_seq_nacks_gaps(self):
        srv, ingest, loop = self._wire_server(strict_seq=True)
        chunk = _sensor_chunks(0)[0]
        assert loop.send(codec.encode_control(codec.OP_OPEN, 3)).ok
        assert loop.send(codec.encode_chunk(
            chunk, stream_id=3, seq=0, timestamp_ns=0,
        )).ok
        r = loop.send(codec.encode_chunk(
            chunk, stream_id=3, seq=2, timestamp_ns=0,
        ))
        assert r.status_name == "seq_gap"
        assert ingest.counters()["n_frames_in"] == 1  # gap not served
        # the retransmit closes the gap; the original jump then lands
        for seq in (1, 2):
            ingest.tick()
            assert loop.send(codec.encode_chunk(
                chunk, stream_id=3, seq=seq, timestamp_ns=0,
            )).ok
        c = ingest.counters()
        assert c["n_seq_gaps"] == 1
        assert c["nacks"]["seq_gap"] == 1
        assert c["n_frames_in"] == 3

    def test_resumable_session_recovers_both_drop_kinds(self):
        """Drops before delivery (frame lost) and after delivery (ACK
        lost) both self-heal through reconnect+RESUME+replay, and the
        served state stays bitwise identical to a clean session."""
        chunks = _sensor_chunks(4, n_frames=32)
        srv, ingest, loop = self._wire_server()
        sess = ResumableSession(
            _DroppingTransport(loop, before={1}, after={2}),
            6,
            drain=ingest.tick,
        )
        assert sess.open().ok
        for c in chunks:
            assert sess.send_chunk(c).ok
            ingest.tick()
        while any(len(q) for q in srv._queues.values()):
            ingest.tick()
        assert sess.n_resumes == 2
        assert ingest.counters()["n_resumed"] == 2
        assert ingest.counters()["n_dup_suppressed"] >= 1  # ACK-lost seq

        comp = api.EPICCompressor(_ecfg())
        step = jax.jit(comp.step)
        state = comp.init()
        for c in chunks:
            state, _ = step(state, c)
        _assert_tree_bitwise(state, srv.state(6), "resumed session")

    def test_resume_refused_raises(self):
        stub = _StubTransport(
            [codec.Reply(codec.NACK_UNKNOWN_STREAM, 1, 0)]
        )
        sess = ResumableSession(stub, 1)
        with pytest.raises(ResumeError, match="unknown_stream"):
            sess.resume()

    def test_resume_gap_outlives_window(self):
        """Server wants a seq the bounded window already rolled past."""
        stub = _StubTransport([codec.Reply(codec.ACK, 1, 1)])
        sess = ResumableSession(stub, 1, window=2)
        sess.next_seq = 5
        sess.last_acked = 0
        sess._window.append((3, b"m3"))
        sess._window.append((4, b"m4"))
        with pytest.raises(ResumeError, match="window"):
            sess.resume()

    def test_resume_noop_when_server_caught_up(self):
        stub = _StubTransport([codec.Reply(codec.ACK, 1, 4)])
        sess = ResumableSession(stub, 1, window=4)
        sess.next_seq = 4
        sess.last_acked = 1  # ACKs lost but the server has everything
        assert sess.resume() == 0
        assert sess.n_resumes == 1


class TestWireClientReconnect:
    class _FakeSock:
        def close(self):
            pass

    def _client(self, monkeypatch, fail_times, **kw):
        attempts = []
        sleeps = []
        fake = self._FakeSock()

        def create(addr, timeout=None):
            attempts.append(addr)
            if 0 < len(attempts) <= fail_times + 1 and len(attempts) > 1:
                if len(attempts) - 1 <= fail_times:
                    raise OSError("connection refused")
            return fake

        monkeypatch.setattr(
            "repro.wire.server.socket.create_connection", create
        )
        cli = WireClient(
            "127.0.0.1", 1, sleep=sleeps.append, **kw
        )
        return cli, attempts, sleeps

    def test_backoff_schedule_bounded_and_exponential(self, monkeypatch):
        cli, attempts, sleeps = self._client(
            monkeypatch, fail_times=3,
            reconnect_attempts=5, backoff_base=0.05, backoff_max=0.15,
        )
        cli.reconnect()
        # 1 construction dial + 3 refused + 1 success
        assert len(attempts) == 5
        assert cli.n_reconnects == 1
        assert sleeps == [0.05, 0.1, 0.15]  # doubled, then capped

    def test_reconnect_gives_up_after_bounded_attempts(self, monkeypatch):
        cli, attempts, sleeps = self._client(
            monkeypatch, fail_times=99,
            reconnect_attempts=3, backoff_base=0.01,
        )
        with pytest.raises(ConnectionError, match="after 3 attempts"):
            cli.reconnect()
        assert len(attempts) == 4  # construction + 3 redials
        assert len(sleeps) == 3
        assert cli.n_reconnects == 0


# ---------------------------------------------------------------------------
# Credit-based flow control + selective retransmit (strict-seq loop)


class TestCreditFlow:
    def _wire_server(self, **kw):
        srv = StreamServer(
            api.EPICCompressor(_ecfg()),
            ServerConfig(
                capacity=2, chunk_frames=CHUNK,
                queue_depth=kw.pop("queue_depth", 2),
            ),
        )
        ingest = IngestServer(srv, **kw)
        return srv, ingest, Loopback(ingest)

    def test_credit_codec_roundtrip(self):
        msg = codec.encode_credit(7, 12)
        ctl = codec.decode_control(msg)
        assert ctl.op == codec.OP_CREDIT
        assert ctl.op_name == "credit"
        assert (ctl.stream_id, ctl.seq) == (7, 12)
        kind, ctl2 = codec.decode_message(msg)
        assert kind == "control" and ctl2 == ctl
        with pytest.raises(codec.WireFormatError, match="encode_credit"):
            codec.encode_control(codec.OP_CREDIT, 7)
        with pytest.raises(codec.WireFormatError, match=">= 1"):
            codec.encode_credit(7, 0)
        with pytest.raises(codec.WireFormatError, match="truncated"):
            codec.decode_control(msg[: codec.CONTROL.size])

    @settings(max_examples=40, deadline=None)
    @given(data=st.data())
    def test_all_control_frames_roundtrip(self, data):
        """Property: every control op (OPEN/CLOSE/RESUME/CREDIT)
        round-trips its stream id and payload bit-exactly."""
        op = data.draw(st.sampled_from(
            (codec.OP_OPEN, codec.OP_CLOSE, codec.OP_RESUME,
             codec.OP_CREDIT)
        ))
        sid = data.draw(st.integers(0, 2**64 - 1))
        if op == codec.OP_RESUME:
            last_acked = data.draw(st.integers(-1, 2**32))
            msg = codec.encode_resume(sid, last_acked)
            expect_seq = last_acked + 1
        elif op == codec.OP_CREDIT:
            requested = data.draw(st.integers(1, 2**32))
            msg = codec.encode_credit(sid, requested)
            expect_seq = requested
        else:
            msg = codec.encode_control(op, sid)
            expect_seq = 0
        ctl = codec.decode_control(msg)
        assert (ctl.op, ctl.stream_id, ctl.seq) == (op, sid, expect_seq)
        assert ctl.op_name == codec._OPS[op]
        kind, ctl2 = codec.decode_message(msg)
        assert kind == "control" and ctl2 == ctl

    def test_every_nack_status_has_exactly_one_reason(self):
        """Table-driven: STATUS_REASONS covers exactly the codes in
        STATUS_NAMES, one non-empty, distinct string each."""
        assert set(codec.STATUS_REASONS) == set(codec.STATUS_NAMES)
        rows = sorted(
            (status, codec.STATUS_NAMES[status],
             codec.STATUS_REASONS[status])
            for status in codec.STATUS_NAMES
        )
        for status, name, reason in rows:
            assert isinstance(reason, str) and reason.strip(), name
        assert len({reason for *_, reason in rows}) == len(rows)

    def test_grant_sized_to_queue_headroom(self):
        srv, ingest, loop = self._wire_server(queue_depth=2)
        assert loop.send(codec.encode_control(codec.OP_OPEN, 1)).ok
        # empty queue: grant = min(requested, headroom)
        r = loop.send(codec.encode_credit(1, 10))
        assert r.ok and r.seq == 2
        # the grant is outstanding: no headroom left to re-grant
        assert loop.send(codec.encode_credit(1, 10)).seq == 0
        chunk = _sensor_chunks(0)[0]
        assert loop.send(codec.encode_chunk(
            chunk, stream_id=1, seq=0, timestamp_ns=0
        )).ok  # consumes one credit; queue now holds one chunk
        assert loop.send(codec.encode_credit(1, 10)).seq == 0
        ingest.tick()  # queue drains: headroom 2, outstanding 1
        r = loop.send(codec.encode_credit(1, 10))
        assert r.ok and r.seq == 1
        c = ingest.counters()
        assert c["n_credit_requests"] == 4
        assert c["n_credit_granted"] == 3
        assert c["credit_outstanding"] == 2
        # unknown stream: refused with the usual NACK
        r = loop.send(codec.encode_credit(404, 1))
        assert r.status_name == "unknown_stream"

    def test_resume_and_close_void_grants(self):
        srv, ingest, loop = self._wire_server(queue_depth=2)
        assert loop.send(codec.encode_control(codec.OP_OPEN, 1)).ok
        assert loop.send(codec.encode_credit(1, 2)).seq == 2
        assert loop.send(codec.encode_resume(1, -1)).ok
        assert ingest.counters()["credit_outstanding"] == 0
        # a fresh request re-grants from scratch
        assert loop.send(codec.encode_credit(1, 2)).seq == 2
        assert loop.send(codec.encode_control(codec.OP_CLOSE, 1)).ok
        assert ingest.counters()["credit_outstanding"] == 0

    def test_session_paces_on_credit_no_backpressure(self):
        chunks = _sensor_chunks(7, n_frames=48)
        # without credit: blind sends into a depth-1 queue NACK
        srv_a, ingest_a, loop_a = self._wire_server(queue_depth=1)
        sess_a = ResumableSession(loop_a, 3, drain=ingest_a.tick)
        assert sess_a.open().ok
        for c in chunks:
            assert sess_a.send_chunk(c).ok
        assert srv_a.n_backpressure > 0
        # with credit: the session asks first and never hits the wall
        srv_b, ingest_b, loop_b = self._wire_server(queue_depth=1)
        sess_b = ResumableSession(
            loop_b, 3, drain=ingest_b.tick, credit=4
        )
        assert sess_b.open().ok
        for c in chunks:
            assert sess_b.send_chunk(c).ok
        assert srv_b.n_backpressure == 0
        assert sess_b.n_credit_requests > 0
        assert sess_b.n_credit_waits > 0  # zero grants paced via drain
        while any(len(q) for q in srv_b._queues.values()):
            ingest_b.tick()
        while any(len(q) for q in srv_a._queues.values()):
            ingest_a.tick()
        _assert_tree_bitwise(
            srv_a.state(3), srv_b.state(3), "credit pacing"
        )

    def test_credit_starvation_without_drain_raises(self):
        srv, ingest, loop = self._wire_server(queue_depth=1)
        sess = ResumableSession(loop, 2, credit=1, max_retries=3)
        assert sess.open().ok
        chunk = _sensor_chunks(0)[0]
        assert sess.send_chunk(chunk).ok  # grant 1, consume 1
        with pytest.raises(ResumeError, match="no drain hook"):
            sess.send_chunk(chunk)  # queue full -> zero grant, no drain

    def test_credit_validation(self):
        with pytest.raises(ValueError, match="credit window"):
            ResumableSession(object(), 1, credit=0)


class _SwallowingTransport:
    """Silently loses data frames with scheduled seqs (synthesizing the
    ACK a fire-and-forget uplink would assume), delivering the rest."""

    def __init__(self, loop, lose=()):
        self.loop = loop
        self.lose = set(lose)

    def send(self, msg):
        if bytes(memoryview(msg)[:4]) == codec.DATA_MAGIC:
            _, _, _, sid, seq, *_ = codec.FRAME_HEADER.unpack_from(
                bytes(msg)[: codec.FRAME_HEADER.size]
            )
            if seq in self.lose:
                self.lose.discard(seq)
                return codec.Reply(codec.ACK, sid, seq)
        return self.loop.send(msg)


class TestSelectiveRetransmit:
    def _strict(self, **kw):
        srv = StreamServer(
            api.EPICCompressor(_ecfg()),
            ServerConfig(capacity=2, chunk_frames=CHUNK, queue_depth=4),
        )
        ingest = IngestServer(srv, strict_seq=True, **kw)
        return srv, ingest, Loopback(ingest)

    def test_gap_nack_carries_first_missing_seq(self):
        srv, ingest, loop = self._strict()
        chunk = _sensor_chunks(0)[0]
        assert loop.send(codec.encode_control(codec.OP_OPEN, 1)).ok
        # nothing served yet: the first missing seq is 0
        r = loop.send(codec.encode_chunk(
            chunk, stream_id=1, seq=2, timestamp_ns=0
        ))
        assert r.status_name == "seq_gap" and r.seq == 0
        assert loop.send(codec.encode_chunk(
            chunk, stream_id=1, seq=0, timestamp_ns=0
        )).ok
        # served through 0: a jump to 3 is missing [1, 3)
        r = loop.send(codec.encode_chunk(
            chunk, stream_id=1, seq=3, timestamp_ns=0
        ))
        assert r.status_name == "seq_gap" and r.seq == 1

    def test_session_replays_exactly_the_missing_slice(self):
        chunks = _sensor_chunks(9, n_frames=48)
        srv, ingest, loop = self._strict()
        sess = ResumableSession(
            _SwallowingTransport(loop, lose={1, 2}),
            5, window=32, drain=ingest.tick,
        )
        assert sess.open().ok
        for c in chunks:
            assert sess.send_chunk(c).ok
            ingest.tick()
        while any(len(q) for q in srv._queues.values()):
            ingest.tick()
        # seqs 1 and 2 were lost in flight; seq 3's NACK named the
        # range and exactly those two frames were replayed
        assert sess.n_retransmits == 2
        assert ingest.counters()["n_frames_in"] == len(chunks)
        comp = api.EPICCompressor(_ecfg())
        step = jax.jit(comp.step)
        state = comp.init()
        for c in chunks:
            state, _ = step(state, c)
        _assert_tree_bitwise(state, srv.state(5), "selective retransmit")

    def test_loss_outliving_window_is_an_error(self):
        chunks = _sensor_chunks(9, n_frames=40)
        srv, ingest, loop = self._strict()
        sess = ResumableSession(
            _SwallowingTransport(loop, lose={0, 1}),
            6, window=2, drain=ingest.tick,
        )
        assert sess.open().ok
        assert sess.send_chunk(chunks[0]).ok  # lost, ACK synthesized
        assert sess.send_chunk(chunks[1]).ok  # lost, ACK synthesized
        # seq 2 pushes seq 0 out of the 2-frame window; the server's
        # gap starts at 0, which the window can no longer supply
        with pytest.raises(ResumeError, match="outlived"):
            sess.send_chunk(chunks[2])


# ---------------------------------------------------------------------------
# Multi-stream traces: interleaving recorded and replayed bit-exactly


class TestMultiStreamTrace:
    def test_record_streams_message_order(self, tmp_path):
        feeds = {
            1: _sensor_chunks(1, n_frames=24),  # 3 chunks
            2: _sensor_chunks(2, n_frames=16),  # 2 chunks
        }
        path = os.path.join(tmp_path, "multi.wtrace")
        n = trace.record_streams(feeds, path, chunk_period_ns=1000)
        assert n == 2 + 5 + 2  # OPENs + data + CLOSEs
        decoded = []
        for rec in trace.TraceReader(path):
            kind, frame = codec.decode_message(rec.message)
            decoded.append((
                rec.timestamp_ns,
                frame.op_name if kind == "control" else "data",
                frame.stream_id,
                frame.seq if kind == "data" else None,
            ))
        assert decoded == [
            (0, "open", 1, None), (0, "data", 1, 0),
            (0, "open", 2, None), (0, "data", 2, 0),
            (1000, "data", 1, 1), (1000, "data", 2, 1),
            (2000, "data", 1, 2), (2000, "close", 2, None),
            (3000, "close", 1, None),
        ]

    def test_interleaved_replay_reaches_bitwise_state_parity(
        self, tmp_path
    ):
        feeds = {
            1: _sensor_chunks(1, n_frames=32),
            2: _sensor_chunks(2, n_frames=24),
        }
        path = os.path.join(tmp_path, "multi.wtrace")
        trace.record_streams(
            feeds, path, chunk_period_ns=1000, open_close=False
        )
        srv = StreamServer(
            api.EPICCompressor(_ecfg()),
            ServerConfig(capacity=2, chunk_frames=CHUNK, queue_depth=2),
        )
        ingest = IngestServer(srv)
        loop = Loopback(ingest)
        for sid in feeds:
            assert loop.send(codec.encode_control(codec.OP_OPEN, sid)).ok
        ticks = []
        replies = []
        trace.replay(
            path, loop.send,
            on_reply=replies.append,
            on_advance=lambda: ticks.append(ingest.tick()),
        )
        assert all(r.ok for r in replies)
        assert len(ticks) == 3  # 4 distinct timestamps -> 3 boundaries
        while any(len(q) for q in srv._queues.values()):
            ingest.tick()
        for sid, chunks in feeds.items():
            comp = api.EPICCompressor(_ecfg())
            step = jax.jit(comp.step)
            state = comp.init()
            for c in chunks:
                state, _ = step(state, c)
            _assert_tree_bitwise(
                state, srv.state(sid), f"interleaved stream {sid}"
            )

    def _loaded_server(self, cfg, trace_writer=None):
        srv = StreamServer(
            api.EPICCompressor(_ecfg()),
            ServerConfig(capacity=2, chunk_frames=CHUNK, queue_depth=1),
        )
        ingest = IngestServer(srv)
        gen = LoadGen(
            cfg, _sensor_chunks(0, n_frames=16), ingest,
            trace_writer=trace_writer,
        )
        summary = gen.run()
        return srv, ingest, summary

    def test_loadgen_trace_replays_bit_exactly(self, tmp_path):
        """The load generator's interleaved multi-stream traffic,
        recorded via ``trace_writer``, replays through a fresh server
        to the identical admissions, NACKs, and per-stream state."""
        cfg = LoadConfig(
            seed=3, ticks=8, arrival_rate=1.0,
            session_len_mu=1.0, session_len_sigma=0.5,
            burst_factor=2.0, burst_every=4,
        )
        path = os.path.join(tmp_path, "load.wtrace")
        with trace.TraceWriter(path) as w:
            srv1, ingest1, summary = self._loaded_server(cfg, w)
        assert w.n_records == summary["n_frames_sent"] + (
            summary["n_arrivals"] + summary["n_closed"]
        )

        srv2 = StreamServer(
            api.EPICCompressor(_ecfg()),
            ServerConfig(capacity=2, chunk_frames=CHUNK, queue_depth=1),
        )
        ingest2 = IngestServer(srv2)
        loop2 = Loopback(ingest2)
        fired = []
        trace.replay(
            path, loop2.send,
            on_advance=lambda: fired.append(ingest2.tick()),
        )
        # ticks with no traffic leave no records; make the totals match
        for _ in range(cfg.ticks - len(fired)):
            ingest2.tick()

        c1, c2 = ingest1.counters(), ingest2.counters()
        assert c1 == c2
        assert srv1.server_counters() == srv2.server_counters()
        assert sorted(srv1.live_sessions) == sorted(srv2.live_sessions)
        for sid in srv1.live_sessions:
            _assert_tree_bitwise(
                srv1.state(sid), srv2.state(sid), f"replayed stream {sid}"
            )


class TestWireClientTimeout:
    def test_wedged_server_surfaces_as_retriable_connection_error(self):
        import socket as _socket
        import threading as _threading

        srv_sock = _socket.socket()
        try:
            srv_sock.bind(("127.0.0.1", 0))
        except (OSError, PermissionError) as e:  # pragma: no cover
            pytest.skip(f"cannot bind local TCP socket: {e}")
        srv_sock.listen(1)
        host, port = srv_sock.getsockname()
        accepted = []
        t = _threading.Thread(  # accept, read nothing, never reply
            target=lambda: accepted.append(srv_sock.accept()),
            daemon=True,
        )
        t.start()
        try:
            client = WireClient(host, port, timeout=0.3)
            with pytest.raises(ConnectionError, match="unresponsive"):
                client.send(codec.encode_control(codec.OP_OPEN, 1))
            assert client.n_timeouts == 1
            # the poisoned socket was closed: a fresh send fails fast
            # instead of hanging (reconnect() is the recovery path)
            with pytest.raises(OSError):
                client.send(codec.encode_control(codec.OP_OPEN, 1))
        finally:
            for conn, _ in accepted:
                conn.close()
            srv_sock.close()
            t.join(timeout=2)
