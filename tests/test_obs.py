"""repro.obs: metrics registry, flight recorder, STATUS introspection.

The observability contract of PR 10 (ROADMAP "tier-1"):

* one :class:`~repro.obs.metrics.MetricsRegistry` backs every counter
  view — ``IngestServer.counters()``, ``StreamServer.server_counters``
  and the registry snapshot must agree because they read the *same*
  cells (checked here after a mixed loss/overload soak, not just on a
  happy path);
* histogram percentiles are ``nan`` on empty (never a crash) and merge
  refuses layout mismatches;
* the :class:`~repro.obs.trace.FlightRecorder` ring is bounded, its
  Chrome-trace dump is valid (pinned against an injected fake clock),
  and the serving tick leaves the documented phase spans + events;
* the wire ``STATUS`` frame returns exactly what host-side
  :func:`~repro.obs.status.collect_status` computes — over loopback
  and over a real TCP socket;
* ``k_trajectory_limit`` bounds the per-stream rung history without
  changing the decision rule;
* :class:`~repro.runtime.fault.FailureInjector` kill points leave
  post-mortem flight dumps.
"""

from __future__ import annotations

import json
import math
import os

import jax
import pytest

from repro import api
from repro.core import pipeline as P
from repro.data import synthetic as SYN
from repro.obs import dump as obs_dump
from repro.obs.metrics import (
    DEFAULT_HI,
    DEFAULT_LO,
    DEFAULT_N_BUCKETS,
    Histogram,
    MetricsRegistry,
    counter_property,
    gauge_property,
)
from repro.obs.status import collect_status
from repro.obs.trace import NULL_SPAN, FlightRecorder
from repro.runtime.fault import FailureInjector, WorkerFailure
from repro.serve import ServerConfig, StreamServer
from repro.serve.adaptive import KLadderController
from repro.serve.degrade import DegradeConfig, DegradeController
from repro.wire import codec
from repro.wire.latency import LatencyHistogram, LatencyRecorder
from repro.wire.loadgen import LoadConfig, LoadGen
from repro.wire.server import IngestServer, Loopback, WireClient

FRAME = 64
PATCH = 16
CHUNK = 8


def _ecfg(**kw):
    base = dict(
        frame_hw=(FRAME, FRAME), patch=PATCH, capacity=32,
        tau=0.10, gamma=0.015, theta=8, window=16,
    )
    base.update(kw)
    return P.EPICConfig(**base)


def _sensor_chunks(seed, n_frames=16, n_obj=4):
    scfg = SYN.StreamConfig(n_frames=n_frames, hw=(FRAME, FRAME), n_obj=n_obj)
    s, _ = SYN.generate_stream(jax.random.PRNGKey(seed), scfg)
    stream = api.SensorChunk(s.frames, s.poses, s.gazes, s.depth)
    return list(api.iter_chunks(stream, CHUNK, remainder="drop"))


def _server(**cfg_kw):
    base = dict(capacity=2, chunk_frames=CHUNK, queue_depth=2)
    base.update(cfg_kw)
    return StreamServer(api.EPICCompressor(_ecfg()), ServerConfig(**base))


# ---------------------------------------------------------------------------
# MetricsRegistry: typed cells, labels, kinds, export
# ---------------------------------------------------------------------------


class TestMetricsRegistry:
    def test_counter_get_or_create_is_stable(self):
        reg = MetricsRegistry()
        c = reg.counter("requests_total")
        c.inc()
        c.inc(4)
        assert reg.counter("requests_total") is c
        assert reg.value("requests_total") == 5

    def test_labels_address_distinct_cells(self):
        reg = MetricsRegistry()
        reg.counter("nacks_total", status="backpressure").inc(3)
        reg.counter("nacks_total", status="bad_crc").inc()
        fam = reg.family("nacks_total")
        assert {dict(lk)["status"]: m.value for lk, m in fam.items()} == {
            "backpressure": 3, "bad_crc": 1,
        }
        # label order never matters
        reg.counter("multi", a=1, b=2).inc()
        assert reg.counter("multi", b=2, a=1).value == 1

    def test_one_kind_per_name(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError, match="is a counter"):
            reg.gauge("x")
        with pytest.raises(TypeError, match="is a counter"):
            reg.histogram("x", phase="q")  # even under fresh labels

    def test_name_and_label_validation(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="invalid metric name"):
            reg.counter("bad name")
        with pytest.raises(ValueError, match="invalid label name"):
            reg.counter("ok", **{"bad-label": 1})

    def test_computed_gauge_reads_live_and_rejects_set(self):
        reg = MetricsRegistry()
        state = {"v": 1}
        g = reg.gauge("live", fn=lambda: state["v"])
        assert g.value == 1
        state["v"] = 7
        assert reg.value("live") == 7
        with pytest.raises(TypeError, match="computed gauge"):
            g.set(0)

    def test_clear_family_keeps_the_kind(self):
        reg = MetricsRegistry()
        reg.counter("gaps", stream=1).inc()
        reg.clear_family("gaps")
        assert reg.family("gaps") == {}
        with pytest.raises(TypeError):
            reg.gauge("gaps")  # the name is still a counter

    def test_value_raises_on_unknown(self):
        with pytest.raises(KeyError):
            MetricsRegistry().value("nope")

    def test_snapshot_is_json_safe(self):
        reg = MetricsRegistry()
        reg.counter("c", kind="a").inc(2)
        reg.gauge("g").set(1.5)
        reg.histogram("h").record(0.01)
        snap = json.loads(json.dumps(reg.snapshot()))
        assert snap["c"]["values"] == [
            {"labels": {"kind": "a"}, "value": 2}
        ]
        assert snap["g"]["values"][0]["value"] == 1.5
        assert snap["h"]["values"][0]["count"] == 1

    def test_merge_counters_add_gauges_take_histograms_fold(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.counter("c").inc(1)
        b.counter("c").inc(2)
        b.gauge("g").set(9)
        a.gauge("live", fn=lambda: 42)
        b.gauge("live", fn=lambda: 0)  # other's computed: ignored
        a.histogram("h").record(0.001)
        b.histogram("h").record(0.002)
        a.merge(b)
        assert a.counter("c").value == 3
        assert a.gauge("g").value == 9
        assert a.gauge("live").value == 42
        assert a.histogram("h").n == 2

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        reg.counter("frames_total", tier=0).inc(5)
        reg.gauge("level").set(2)
        reg.histogram("lat", n_buckets=4).record(0.01)
        text = reg.to_prometheus()
        assert "# TYPE frames_total counter" in text
        assert 'frames_total{tier="0"} 5' in text
        assert "level 2" in text
        assert 'lat_bucket{le="+Inf"} 1' in text
        assert "lat_count 1" in text


# ---------------------------------------------------------------------------
# Histogram: empty-nan pin, interpolation, layout-checked merge
# ---------------------------------------------------------------------------


class TestHistogram:
    def test_empty_percentile_is_nan_and_summary_none(self):
        h = Histogram()
        assert math.isnan(h.percentile(0.5))
        assert math.isnan(h.percentile(0.99))
        s = h.summary()
        assert s["count"] == 0 and s["p50_ms"] is None

    def test_single_sample_bounds(self):
        h = Histogram()
        h.record(0.010)
        for q in (0.5, 0.95, 0.99):
            p = h.percentile(q)
            assert 0 < p <= h.max_s
        assert h.summary()["count"] == 1

    def test_percentiles_are_monotone(self):
        h = Histogram()
        for i in range(1, 101):
            h.record(i * 1e-3)
        assert h.percentile(0.5) <= h.percentile(0.95) <= h.percentile(0.99)
        assert abs(h.percentile(0.5) - 0.050) < 0.010  # ~9% buckets
        assert h.max_s == pytest.approx(0.100)

    def test_merge_is_count_exact(self):
        a, b, both = Histogram(), Histogram(), Histogram()
        for i in range(50):
            a.record(i * 1e-3), both.record(i * 1e-3)
        for i in range(50, 100):
            b.record(i * 1e-3), both.record(i * 1e-3)
        a.merge(b)
        assert a.counts == both.counts
        assert a.n == both.n == 100
        assert a.percentile(0.95) == both.percentile(0.95)

    def test_merge_refuses_layout_mismatch(self):
        a = Histogram(n_buckets=8)
        for other in (
            Histogram(n_buckets=16),
            Histogram(lo=1e-3, n_buckets=8),
            Histogram(hi=60.0, n_buckets=8),
        ):
            with pytest.raises(ValueError, match="bucket layouts"):
                a.merge(other)

    def test_latency_histogram_shares_the_default_layout(self):
        assert LatencyHistogram().layout == (
            DEFAULT_LO, DEFAULT_HI, DEFAULT_N_BUCKETS
        )
        # so recorder merges across pools can never hit the mismatch path
        Histogram().merge(LatencyHistogram())

    def test_recorder_routes_through_a_shared_registry(self):
        reg = MetricsRegistry()
        rec = LatencyRecorder(metrics=reg)
        rec.observe(0.0, 0.5, 1.5)
        assert rec.n == 1
        fam = reg.family("ingest_latency_seconds")
        assert {dict(lk)["phase"] for lk in fam} == {
            "queue_wait", "service", "total"
        }
        assert reg.value(
            "ingest_latency_seconds", phase="total"
        )["count"] == 1


# ---------------------------------------------------------------------------
# counter_property / gauge_property: attribute views over registry cells
# ---------------------------------------------------------------------------


class _Instrumented:
    hits = counter_property("hits_total")
    level = gauge_property("level", cast=int)

    def __init__(self):
        self.metrics = MetricsRegistry()
        self.hits = 0
        self.level = 0


class TestAttributeViews:
    def test_read_modify_write_hits_the_cell(self):
        obj = _Instrumented()
        obj.hits += 1
        obj.hits += 2
        assert obj.hits == 3
        assert obj.metrics.counter("hits_total").value == 3
        obj.hits = 10  # checkpoint-restore style overwrite
        assert obj.metrics.value("hits_total") == 10

    def test_gauge_property_casts(self):
        obj = _Instrumented()
        obj.level = 2.9
        assert obj.level == 2
        assert obj.metrics.gauge("level").value == 2


# ---------------------------------------------------------------------------
# FlightRecorder: ring bound, clock-pinned Chrome trace, orphans
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


class TestFlightRecorder:
    def test_ring_is_bounded_oldest_first(self):
        rec = FlightRecorder(capacity=3, clock=_FakeClock())
        for i in range(7):
            rec.begin_tick(i)
            rec.end_tick()
        ticks = rec.ticks()
        assert [t["tick"] for t in ticks] == [4, 5, 6]
        assert rec.n_ticks_recorded == 7

    def test_begin_tick_auto_closes_predecessor(self):
        rec = FlightRecorder(capacity=4, clock=_FakeClock())
        rec.begin_tick(0)
        rec.begin_tick(1)  # no end_tick(0)
        rec.end_tick()
        assert [t["tick"] for t in rec.ticks()] == [0, 1]

    def test_chrome_trace_is_pinned_against_the_clock(self):
        rec = FlightRecorder(capacity=4, clock=_FakeClock())
        rec.begin_tick(0)                      # t0 = 1
        with rec.span("dispatch"):             # 2 .. 3
            pass
        rec.event("admit", stream=7, slot=0)   # 4
        rec.end_tick()                         # t1 = 5
        doc = json.loads(json.dumps(rec.to_chrome_trace()))
        by_name = {e["name"]: e for e in doc["traceEvents"]}
        tick = by_name["tick 0"]
        assert tick["ph"] == "X"
        assert (tick["ts"], tick["dur"]) == (1e6, 4e6)
        span = by_name["dispatch"]
        assert (span["ts"], span["dur"]) == (2e6, 1e6)
        admit = by_name["admit"]
        assert admit["ph"] == "i" and admit["ts"] == 4e6
        assert admit["args"] == {"stream": 7, "slot": 0, "tick": 0}
        assert doc["otherData"]["ticks_retained"] == 1

    def test_orphan_events_survive_without_an_open_tick(self):
        rec = FlightRecorder(capacity=2, clock=_FakeClock())
        rec.event("checkpoint", step=3)
        doc = rec.to_chrome_trace()
        names = [e["name"] for e in doc["traceEvents"] if e["ph"] == "i"]
        assert names == ["checkpoint"]
        assert rec.n_events == 1

    def test_non_json_event_args_are_stringified_on_dump(self):
        rec = FlightRecorder(capacity=2, clock=_FakeClock())
        rec.begin_tick(0)
        rec.event("evict", stream=("sess", 3))
        rec.end_tick()
        doc = json.loads(json.dumps(rec.to_chrome_trace()))
        (ev,) = [e for e in doc["traceEvents"] if e["ph"] == "i"]
        assert ev["args"]["stream"] == "('sess', 3)"

    def test_dump_and_cli_summary(self, tmp_path):
        rec = FlightRecorder(capacity=4, clock=_FakeClock())
        rec.begin_tick(0)
        with rec.span("ingest"):
            pass
        rec.end_tick()
        path = rec.dump(str(tmp_path / "trace.json"))
        assert obs_dump.main([path]) == 0
        with open(path) as f:
            text = obs_dump.summarize(json.load(f))
        assert "ticks retained: 1" in text and "ingest" in text

    def test_summarize_rejects_non_traces(self):
        with pytest.raises(ValueError, match="no traceEvents"):
            obs_dump.summarize({"foo": 1})

    def test_null_span_and_capacity_validation(self):
        with NULL_SPAN:
            pass
        with pytest.raises(ValueError, match="capacity"):
            FlightRecorder(capacity=0)


# ---------------------------------------------------------------------------
# StreamServer integration: phase spans, events, registry == views
# ---------------------------------------------------------------------------


class TestServerTracing:
    def test_tick_leaves_phase_spans_and_events(self):
        srv = _server()
        srv.recorder = FlightRecorder(capacity=8)
        srv.admit("a")
        chunks = _sensor_chunks(0, n_frames=24)
        for c in chunks:
            srv.submit("a", c)
            srv.tick()
        ticks = srv.recorder.ticks()
        assert len(ticks) == len(chunks)
        span_names = {s[0] for t in ticks for s in t["spans"]}
        assert span_names == {"ingest", "schedule", "dispatch", "readback"}
        events = [e[0] for t in ticks for e in t["events"]]
        assert events.count("admit") == 0  # admit happened pre-tick 0
        srv.close("a")
        srv.recorder.begin_tick(srv.n_ticks)
        srv.admit("b")
        srv.close("b")
        srv.recorder.end_tick()
        last = srv.recorder.ticks()[-1]
        assert [e[0] for e in last["events"]] == ["admit", "evict"]

    def test_registry_backs_server_counters_bit_identically(self):
        srv = _server()
        srv.admit("a")
        for c in _sensor_chunks(0, n_frames=16):
            srv.submit("a", c)
            srv.tick()
        sc = srv.server_counters()
        reg = srv.metrics
        assert sc["n_ticks"] == reg.value("serve_ticks_total")
        assert sc["n_admitted"] == reg.value("serve_admitted_total")
        assert sc["n_evicted"] == reg.value("serve_evicted_total")
        assert sc["n_dispatches"] == reg.value("serve_dispatches_total")
        assert sc["frames_served"] == reg.value("serve_frames_served_total")
        assert sc["n_live"] == reg.value("serve_live_streams")
        assert sc["degrade_level"] == reg.value("serve_degrade_level")
        # and the export path carries the same numbers
        assert f"serve_ticks_total {sc['n_ticks']}" in reg.to_prometheus()


# ---------------------------------------------------------------------------
# Three-view consistency after a mixed loss/overload soak
# ---------------------------------------------------------------------------


class TestCounterConsistency:
    def _soak(self):
        """A deliberately hostile little run: overload (queue_depth 1,
        double submits), unknown-stream sends, an out-of-order replay,
        and a seq gap — every NACK family and gap counter fires."""
        srv = _server(capacity=2, queue_depth=1)
        srv.degrade = DegradeController(
            DegradeConfig(), metrics=srv.metrics
        )
        ingest = IngestServer(srv)
        loop = Loopback(ingest)
        chunks = _sensor_chunks(1, n_frames=64)
        assert loop.send(codec.encode_control(codec.OP_OPEN, 1)).ok
        seq = 0
        for t in range(4):
            for c in (chunks[2 * t], chunks[2 * t + 1]):
                loop.send(codec.encode_chunk(
                    c, stream_id=1, seq=seq, timestamp_ns=seq
                ))  # second submit of each tick hits backpressure
                seq += 1
            # loss-shaped traffic: an unknown stream, a stale replay
            loop.send(codec.encode_chunk(
                chunks[0], stream_id=99, seq=0, timestamp_ns=0
            ))
            loop.send(codec.encode_chunk(
                chunks[0], stream_id=1, seq=0, timestamp_ns=0
            ))
            ingest.tick()
        # a dropped frame: jump the cursor → counted seq gap
        loop.send(codec.encode_chunk(
            chunks[0], stream_id=1, seq=seq + 3, timestamp_ns=0
        ))
        ingest.tick()
        return srv, ingest

    def test_all_three_views_read_the_same_cells(self):
        srv, ingest = self._soak()
        reg = srv.metrics
        assert ingest.metrics is reg  # one registry end to end

        wc = ingest.counters()
        assert wc["nacks"] != {} and wc["n_seq_gaps"] > 0
        for key, metric in (
            ("n_messages", "wire_messages_total"),
            ("n_frames_in", "wire_frames_in_total"),
            ("n_opened", "wire_opened_total"),
            ("n_closed", "wire_closed_total"),
            ("n_resumed", "wire_resumed_total"),
            ("n_dup_suppressed", "wire_dup_suppressed_total"),
            ("n_credit_requests", "wire_credit_requests_total"),
            ("n_credit_granted", "wire_credit_granted_total"),
            ("credit_outstanding", "wire_credit_outstanding"),
        ):
            assert wc[key] == reg.value(metric), key
        assert wc["nacks"] == {
            dict(lk)["status"]: m.value
            for lk, m in reg.family("wire_nacks_total").items()
        }
        assert wc["seq_gaps_by_stream"] == {
            dict(lk)["stream"]: m.value
            for lk, m in reg.family("wire_seq_gaps_total").items()
        }

        sc = srv.server_counters()
        assert sc["n_backpressure"] > 0
        for key, metric in (
            ("n_ticks", "serve_ticks_total"),
            ("n_admitted", "serve_admitted_total"),
            ("n_backpressure", "serve_backpressure_total"),
            ("n_dispatches", "serve_dispatches_total"),
            ("frames_served", "serve_frames_served_total"),
            ("n_live", "serve_live_streams"),
            ("n_shed_stale", "serve_shed_stale_total"),
            ("degrade_level", "serve_degrade_level"),
        ):
            assert sc[key] == reg.value(metric), key
        # the degrade controller shares the registry too
        assert srv.degrade.counters()["n_observed"] == reg.value(
            "degrade_observed_total"
        )
        # and one snapshot carries all three families
        snap = reg.snapshot()
        for name in ("serve_ticks_total", "wire_messages_total",
                     "degrade_observed_total"):
            assert name in snap


# ---------------------------------------------------------------------------
# STATUS: loopback + TCP both return the host-side truth
# ---------------------------------------------------------------------------


class TestStatus:
    def _loaded_ingest(self):
        srv = _server()
        srv.degrade = DegradeController(
            DegradeConfig(), metrics=srv.metrics
        )
        ingest = IngestServer(srv)
        loop = Loopback(ingest)
        assert loop.send(codec.encode_control(codec.OP_OPEN, 5)).ok
        for seq, c in enumerate(_sensor_chunks(2, n_frames=16)):
            assert loop.send(codec.encode_chunk(
                c, stream_id=5, seq=seq, timestamp_ns=seq
            )).ok
            ingest.tick()
        return ingest, loop

    def test_loopback_status_equals_collect_status(self):
        ingest, loop = self._loaded_ingest()
        got = loop.status()
        with ingest.lock:
            want = json.loads(json.dumps(collect_status(ingest)))
        assert got == want
        assert got["schema"] == 1
        assert got["tick"] == ingest.srv.n_ticks > 0
        assert got["tiers"][0]["n_active"] == 1
        assert got["seq_cursors"] == {"5": 1}
        assert got["degrade"]["attached"] is True
        assert got["wire_counters"]["n_frames_in"] == 2
        # every NACK code a client can receive is in the reply
        assert set(got["status_reasons"]) == {
            str(c) for c in codec.STATUS_REASONS
        }

    def test_status_roundtrips_the_codec(self):
        ingest, loop = self._loaded_ingest()
        raw = loop.roundtrip(codec.encode_control(codec.OP_STATUS, 0))
        kind, payload = codec.decode_message(raw)
        assert kind == "status"
        again = loop.status()
        # each STATUS request is itself a counted wire message, so the
        # second snapshot drifts by exactly one n_messages
        assert again["wire_counters"].pop("n_messages") == (
            payload["wire_counters"].pop("n_messages") + 1
        )
        assert payload == again

    def test_status_over_tcp(self):
        ingest, _ = self._loaded_ingest()
        try:
            host, port = ingest.start_tcp_in_thread()
        except (OSError, PermissionError) as e:  # pragma: no cover
            pytest.skip(f"cannot bind local TCP socket: {e}")
        try:
            with WireClient(host, port) as client:
                got = client.status()
            with ingest.lock:
                want = json.loads(json.dumps(collect_status(ingest)))
            assert got == want
        finally:
            ingest.stop()


# ---------------------------------------------------------------------------
# k_trajectory_limit: bounded rung history, unchanged decisions
# ---------------------------------------------------------------------------


class TestKTrajectoryLimit:
    def test_controller_ring_keeps_the_most_recent(self):
        # overflow climbs; peak_full=100 never satisfies the shrink
        # margin, so the rung saturates at the top and stays
        ctl = KLadderController((4, 8, 16), history_limit=3)
        for _ in range(7):
            ctl.begin_chunk()
            ctl.update(overflow=1, peak_full=100)
        assert list(ctl.k_trajectory) == [16, 16, 16]
        unbounded = KLadderController((4, 8, 16))
        for _ in range(7):
            unbounded.begin_chunk()
            unbounded.update(overflow=1, peak_full=100)
        assert list(unbounded.k_trajectory) == [4, 8] + [16] * 5
        assert list(unbounded.k_trajectory)[-3:] == list(ctl.k_trajectory)

    def test_history_limit_validation(self):
        with pytest.raises(ValueError, match="history_limit"):
            KLadderController((4, 8), history_limit=0)
        with pytest.raises(ValueError, match="k_trajectory_limit"):
            StreamServer(
                api.EPICCompressor(_ecfg()),
                ServerConfig(k_trajectory_limit=0),
            )

    def test_decisions_identical_with_and_without_the_bound(self):
        runs = []
        for limit in (None, 2):
            ctl = KLadderController((4, 8, 16), history_limit=limit)
            ks = []
            for i in range(12):
                ks.append(ctl.begin_chunk())
                ctl.update(
                    overflow=1 if i % 3 == 0 else 0,
                    peak_full=1 if i % 3 == 2 else 100,
                )
            runs.append(ks)
        assert runs[0] == runs[1]

    def test_server_config_bounds_per_stream_history(self):
        srv = StreamServer(
            api.EPICCompressor(_ecfg(prefilter_k=4)),
            ServerConfig(
                capacity=1, chunk_frames=CHUNK, queue_depth=2,
                k_ladder=(4, 8), k_trajectory_limit=2,
            ),
        )
        srv.admit("a")
        for c in _sensor_chunks(0, n_frames=32):
            srv.submit("a", c)
            srv.tick()
        traj = srv.telemetry("a").as_dict()["k_trajectory"]
        assert len(traj) == 2  # 4 chunks served, ring kept the last 2


# ---------------------------------------------------------------------------
# FailureInjector: kill points leave flight-dump post-mortems
# ---------------------------------------------------------------------------


class TestFaultDumps:
    def test_kill_point_dumps_before_raising(self, tmp_path):
        rec = FlightRecorder(capacity=4, clock=_FakeClock())
        rec.begin_tick(0)
        rec.event("nack", status="backpressure")
        inj = FailureInjector(
            [("mid_tick", 3)], recorder=rec, dump_dir=str(tmp_path)
        )
        inj.maybe_fail("benign")  # not a kill point
        with pytest.raises(WorkerFailure):
            inj.maybe_fail(("mid_tick", 3))
        (path,) = inj.dump_paths
        assert os.path.basename(path) == "flight-mid_tick---3-0.json"
        with open(path) as f:
            doc = json.load(f)
        assert any(
            e["name"] == "nack" for e in doc["traceEvents"]
        )
        # each point fires once: the replay of the same point survives
        inj.maybe_fail(("mid_tick", 3))

    def test_without_a_recorder_nothing_is_written(self, tmp_path):
        inj = FailureInjector(["x"], dump_dir=str(tmp_path))
        with pytest.raises(WorkerFailure):
            inj.maybe_fail("x")
        assert inj.dump_paths == [] and os.listdir(str(tmp_path)) == []

    def test_dump_failure_never_masks_the_fault(self, tmp_path):
        rec = FlightRecorder(capacity=2, clock=_FakeClock())
        inj = FailureInjector(
            ["x"], recorder=rec,
            dump_dir=str(tmp_path / "missing" / "dir"),
        )
        with pytest.raises(WorkerFailure):
            inj.maybe_fail("x")
        assert inj.dump_paths == []


# ---------------------------------------------------------------------------
# LoadGen RTT: wall-clock percentiles with deterministic sample counts
# ---------------------------------------------------------------------------


class TestLoadGenRTT:
    def test_rtt_counts_every_send(self):
        srv = _server(capacity=4, queue_depth=2)
        gen = LoadGen(
            LoadConfig(seed=3, ticks=6, arrival_rate=1.0),
            _sensor_chunks(0, n_frames=16), IngestServer(srv),
        )
        s = gen.run()
        rtt = s["rtt"]
        sends = (
            s["n_admitted"] + s["n_rejected"]  # OPENs
            + s["n_frames_sent"] + s["n_closed"]
        )
        assert rtt["count"] == sends > 0
        assert rtt["p50_ms"] is not None and rtt["max_ms"] > 0
