"""Import shim: real ``hypothesis`` when installed, else a tiny
deterministic fallback sampler.

The tier-1 suite must collect and run green without optional
dependencies (see requirements-dev.txt).  When ``hypothesis`` is absent,
property tests still execute ``max_examples`` times against a seeded
``random.Random`` stream — far weaker than hypothesis (no shrinking, no
adaptive search) but enough to keep the properties exercised in CI.

Only the strategy surface the test suite actually uses is implemented:
``integers / floats / booleans / sampled_from / lists / data``.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised when the real package is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: _Data(rng))

    class _Data:
        """Stand-in for ``st.data()``'s interactive draw object."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy._sample(self._rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda r: seq[r.randrange(len(seq))])

        @staticmethod
        def lists(elements, min_size=0, max_size=None, unique=False):
            cap = min_size if max_size is None else max_size

            def sample(r):
                n = r.randint(min_size, cap)
                if not unique:
                    return [elements._sample(r) for _ in range(n)]
                out, seen, tries = [], set(), 0
                while len(out) < n and tries < 10_000:
                    v = elements._sample(r)
                    tries += 1
                    if v not in seen:
                        seen.add(v)
                        out.append(v)
                return out

            return _Strategy(sample)

        @staticmethod
        def data():
            return _DataStrategy()

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**named_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                for i in range(n):
                    rng = random.Random(0xEC1C0 + i)
                    drawn = {
                        name: s._sample(rng)
                        for name, s in named_strategies.items()
                    }
                    fn(*args, **kwargs, **drawn)

            # Hide the drawn parameters from pytest's fixture resolution
            # (the real hypothesis does the same via its own wrapper).
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items()
                    if name not in named_strategies
                ]
            )
            del wrapper.__wrapped__
            return wrapper

        return deco
