"""Sparse TRD v2 test suite: patch-side sparsity, fused∘sparse
composition, and the adaptive-K controller.

Pins the PR-4 contracts on top of the PR-3 sparse TRD:

* ``compact_salient_patches`` selection semantics (composite
  (salient, has-passing-entry) key, newest-first entry parity trick
  mirrored onto the patch axis);
* **patch-compacted bitwise parity with the dense patch axis whenever
  at most P_k salient patches exist** — at the ``tsrc_step`` level, per
  backend, under jit, and through the chunked ``EPICCompressor``
  session (with a learned-saliency model so compaction is real);
* conservative ``n_patch_overflow`` truncation semantics;
* fused∘sparse: the fused kernel on gathered candidate slabs is
  bitwise the ``"pallas"`` backend's scores on the same slabs, and the
  whole step composes prefilter + fused bitwise with the dense path;
* adaptive-K: deterministic trajectory, never-moves == fixed-K bitwise,
  ladder fail-fast validation;
* ``patch_k`` fail-fast validation, graph-construction memoization, and
  the measured patch-compacted ``dc_traffic_bytes`` accounting (dense
  runs unchanged).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import dc_buffer as dcb
from repro.core import geometry as geo
from repro.core import hir
from repro.core import pipeline as P
from repro.core import tsrc as tsrc_mod
from repro.data import synthetic as SYN
from repro.kernels.reproject_match import sparse as sparse_mod
from repro.kernels.reproject_match.fused import reproject_match_fused
from repro.kernels.reproject_match.ops import reproject_match

FRAME = 64
PATCH = 16
N_PATCHES = (FRAME // PATCH) ** 2


def _intr(hw=FRAME):
    return geo.Intrinsics.create(0.8 * hw, hw / 2.0, hw / 2.0)


def _tree_equal_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# compact_salient_patches unit semantics
# ---------------------------------------------------------------------------


class TestCompactSalientPatches:
    def _compact(self, salient, has_entry_rows, k):
        n = has_entry_rows.shape[0]
        passes = jnp.ones((n,), bool)
        return sparse_mod.compact_salient_patches(
            salient, has_entry_rows, passes, k=k
        )

    def test_all_salient_selected_when_under_k(self):
        salient = jnp.array([True, False, True, False, True, False])
        overlap = jnp.zeros((3, 6), bool)
        pc = self._compact(salient, overlap, k=4)
        assert int(pc.n_salient) == 3
        assert int(pc.n_compacted) == 3
        assert int(pc.n_overflow) == 0
        chosen = set(np.asarray(pc.idx[pc.real]).tolist())
        assert chosen == {0, 2, 4}

    def test_matchable_salient_patches_win_slots_under_truncation(self):
        # 4 salient patches, only room for 2; entries overlap patches 3, 5.
        salient = jnp.array([True, True, False, True, False, True])
        overlap = jnp.zeros((2, 6), bool).at[0, 3].set(True).at[1, 5].set(
            True
        )
        pc = self._compact(salient, overlap, k=2)
        assert int(pc.n_overflow) == 2
        assert set(np.asarray(pc.idx).tolist()) == {3, 5}
        assert bool(jnp.all(pc.real))

    def test_nonsalient_fillers_marked_not_real(self):
        salient = jnp.zeros((6,), bool).at[2].set(True)
        pc = self._compact(salient, jnp.zeros((2, 6), bool), k=3)
        assert int(pc.n_compacted) == 1
        assert int(jnp.sum(pc.real.astype(jnp.int32))) == 1
        assert int(pc.idx[jnp.argmax(pc.real)]) == 2

    def test_overlap_from_nonpassing_entry_does_not_rank(self):
        salient = jnp.array([True, True, False, False])
        overlap = jnp.ones((1, 4), bool)
        passes = jnp.array([False])  # entry overlaps all but doesn't pass
        pc = sparse_mod.compact_salient_patches(
            salient, overlap, passes, k=1
        )
        # Both salient patches rank equally (no passing entry): the
        # lowest index wins the single slot.
        assert int(pc.idx[0]) == 0
        assert int(pc.n_overflow) == 1


# ---------------------------------------------------------------------------
# Patch-compacted step == dense patch axis (no truncation), per backend
# ---------------------------------------------------------------------------


class TestPatchCompactionParity:
    CAP = 32

    def _frames(self, seed=0):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        f1 = jax.random.uniform(k1, (FRAME, FRAME, 3))
        f2 = f1.at[:, FRAME // 2 :].set(
            jax.random.uniform(k2, (FRAME, FRAME // 2, 3))
        )
        return f1, f2

    def _run_steps(
        self, prefilter_k, patch_k, backend="ref", jit=False, n_sal=2
    ):
        buf_cfg = dcb.DCBufferConfig(capacity=self.CAP, patch=PATCH)
        cfg = tsrc_mod.TSRCConfig(
            window=32, backend=backend,
            prefilter_k=prefilter_k, patch_k=patch_k,
        )
        # Partial saliency so P_k < M compaction is actually exercised.
        sal = jnp.zeros((N_PATCHES,), bool).at[jnp.arange(n_sal)].set(True)
        common = (
            jnp.full((FRAME, FRAME), 3.0), sal, jnp.ones((N_PATCHES,)),
            jnp.eye(4),
        )
        step = tsrc_mod.tsrc_step
        if jit:
            step = jax.jit(step, static_argnames=("buf_cfg", "cfg"))
        f1, f2 = self._frames()
        buf = dcb.init(buf_cfg)
        buf, _ = step(
            buf, buf_cfg, cfg, f1, *common, jnp.float32(0), _intr()
        )
        buf, stats = step(
            buf, buf_cfg, cfg, f2, *common, jnp.float32(1), _intr()
        )
        return buf, stats

    @pytest.mark.parametrize("jit", [False, True])
    def test_compacted_bitwise_equals_dense_patch_axis(self, jit):
        """P_k >= n_salient never truncates: buffer and every shared
        counter equal the patch-dense sparse run bit for bit."""
        dense_p = self._run_steps(self.CAP, 0, jit=jit)
        comp_p = self._run_steps(self.CAP, 2, jit=jit)
        # State bitwise; stats equal except the two patch-compaction
        # observability leaves.
        _tree_equal_bitwise(dense_p[0], comp_p[0])
        _tree_equal_bitwise(
            dense_p[1]._replace(n_patch_checked=jnp.int32(0)),
            comp_p[1]._replace(n_patch_checked=jnp.int32(0)),
        )
        assert int(comp_p[1].n_patch_overflow) == 0
        assert int(comp_p[1].n_patch_checked) == 2
        assert int(dense_p[1].n_patch_checked) == 0

    def test_compacted_bitwise_equals_fully_dense(self):
        """Both-axis sparsity (entry top-K at capacity + patch top-P_k
        over the salient count) == the fully dense step, bit for bit."""
        dense = self._run_steps(0, 0, n_sal=3)
        both = self._run_steps(self.CAP, 3, n_sal=3)
        _tree_equal_bitwise(
            dense[0], both[0]
        )
        _tree_equal_bitwise(
            dense[1]._replace(n_patch_checked=jnp.int32(0)),
            both[1]._replace(n_patch_checked=jnp.int32(0)),
        )

    @pytest.mark.parametrize("backend", ["pallas", "pallas_tiled", "fused"])
    def test_parity_on_every_backend(self, backend):
        dense = self._run_steps(0, 0, backend="ref")
        comp_p = self._run_steps(self.CAP, 2, backend=backend)
        _tree_equal_bitwise(dense[0], comp_p[0])
        assert int(comp_p[1].n_patch_overflow) == 0

    def test_patch_k_at_least_m_is_identity(self):
        """P_k >= M skips compaction entirely (identity permutation):
        bitwise the patch-dense path including the zero counters."""
        a = self._run_steps(self.CAP, 0)
        b = self._run_steps(self.CAP, N_PATCHES)
        c = self._run_steps(self.CAP, N_PATCHES + 7)
        _tree_equal_bitwise(a, b)
        _tree_equal_bitwise(a, c)
        assert int(b[1].n_patch_checked) == 0

    def test_patch_truncation_is_conservative(self):
        """P_k < n_salient drops salient patches from the match algebra
        only: extra insertions, never false matches; overflow counted."""
        dense_p, dense_stats = self._run_steps(self.CAP, 0, n_sal=4)
        _, trunc_stats = self._run_steps(self.CAP, 1, n_sal=4)
        assert int(trunc_stats.n_patch_overflow) == 3
        assert int(trunc_stats.n_patch_checked) == 1
        assert int(trunc_stats.n_matched) <= int(dense_stats.n_matched)
        assert int(trunc_stats.n_inserted) >= int(dense_stats.n_inserted)
        assert int(trunc_stats.n_matched) + int(trunc_stats.n_inserted) == (
            int(trunc_stats.n_salient)
        )

    def test_patch_only_sparsity_without_prefilter(self):
        """patch_k > 0 with prefilter_k == 0 runs the sparse machinery
        with the candidate budget at capacity — bitwise dense, zero
        entry overflow."""
        dense = self._run_steps(0, 0)
        ponly = self._run_steps(0, 2)
        _tree_equal_bitwise(dense[0], ponly[0])
        assert int(ponly[1].n_prefilter_overflow) == 0


# ---------------------------------------------------------------------------
# Fused ∘ sparse composition
# ---------------------------------------------------------------------------


class TestFusedSparseComposition:
    CAP = 32
    K = 8

    def _slabs(self, seed=3):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        rgb = jax.random.uniform(k1, (self.K, PATCH, PATCH, 3))
        dep = jax.random.uniform(k2, (self.K, PATCH, PATCH)) * 2 + 1.0
        orig = jax.random.uniform(k3, (self.K, 2)) * (FRAME - PATCH)
        t_rel = jnp.broadcast_to(jnp.eye(4), (self.K, 4, 4))
        frame = jax.random.uniform(k1, (FRAME, FRAME, 3))
        return rgb, dep, orig, t_rel, frame

    def test_fused_scores_bitwise_pallas_on_candidate_slabs(self):
        """The fused kernel's (diff, coverage, bbox) on a gathered
        candidate slab are bitwise the "pallas" backend's on the same
        slab, and its mask rows are exactly the thresholded scores."""
        rgb, dep, orig, t_rel, frame = self._slabs()
        tau, o_min, c_min, window = 0.1, 0.5, 0.6, 32
        d_f, c_f, b_f, pair, ovok = reproject_match_fused(
            rgb, dep, orig, t_rel, frame, _intr(),
            window=window, tau=tau, o_min=o_min, c_min=c_min,
        )
        d_p, c_p, b_p = reproject_match(
            rgb, dep, orig, t_rel, frame, _intr(),
            window=window, backend="pallas",
        )
        _tree_equal_bitwise((d_f, c_f, b_f), (d_p, c_p, b_p))
        # Mask rows == thresholds applied to those very scores.
        _, origins = tsrc_mod.extract_patches(
            jnp.zeros((FRAME, FRAME, 3)), PATCH
        )
        overlap = geo.bbox_overlap_fraction(
            b_p[:, None, :], origins[None, :, :], PATCH
        )
        np.testing.assert_array_equal(
            np.asarray(ovok), np.asarray(overlap >= o_min)
        )
        entry_ok = (d_p <= tau) & (c_p >= c_min)
        np.testing.assert_array_equal(
            np.asarray(pair), np.asarray(entry_ok[:, None] & ovok)
        )

    @pytest.mark.parametrize("patch_k", [0, 2])
    def test_step_fused_sparse_bitwise_vs_pallas_sparse(self, patch_k):
        """tsrc_step with backend="fused" + prefilter no longer falls
        back: whole step bitwise vs the "pallas" sparse path."""
        h = TestPatchCompactionParity()
        a = h._run_steps(self.CAP, patch_k, backend="pallas")
        b = h._run_steps(self.CAP, patch_k, backend="fused")
        _tree_equal_bitwise(a, b)

    def test_step_fused_sparse_bitwise_vs_dense(self):
        h = TestPatchCompactionParity()
        dense = h._run_steps(0, 0, backend="ref")
        fused = h._run_steps(self.CAP, 2, backend="fused")
        _tree_equal_bitwise(dense[0], fused[0])


# ---------------------------------------------------------------------------
# Chunked-session parity with a learned saliency model (real compaction)
# ---------------------------------------------------------------------------


class TestSessionPatchSparsity:
    @pytest.fixture(scope="class")
    def stream(self):
        scfg = SYN.StreamConfig(n_frames=24, hw=(FRAME, FRAME), n_obj=4)
        s, _ = SYN.generate_stream(jax.random.PRNGKey(2), scfg)
        return api.SensorChunk(s.frames, s.poses, s.gazes, s.depth)

    @pytest.fixture(scope="class")
    def models(self, stream):
        """HIR with its head bias centred at the stream's median logit,
        so per-frame saliency is genuinely partial (random init tends to
        saturate the binary threshold all-or-nothing)."""
        from repro.core import depth as depth_mod

        params = hir.init_params(jax.random.PRNGKey(7))
        grid = FRAME // PATCH
        rgb64 = jax.vmap(
            lambda f: depth_mod.resize_image(f, hir.HIR_INPUT)
        )(stream.frames)
        heat = jax.vmap(
            lambda g: hir.gaze_heatmap(g, hir.HIR_INPUT, (FRAME, FRAME))
        )(stream.gazes)
        logits = hir.forward(params, rgb64, heat, grid)
        params = dict(params)
        params["b3"] = params["b3"] - jnp.median(logits)
        return P.EPICModels(depth_params=None, hir_params=params)

    def _cfg(self, prefilter_k=0, patch_k=0):
        return P.EPICConfig(
            frame_hw=(FRAME, FRAME), patch=PATCH, capacity=48,
            tau=0.10, gamma=0.015, theta=8, window=16,
            prefilter_k=prefilter_k, patch_k=patch_k,
        )

    def test_session_bitwise_with_real_compaction(self, stream, models):
        """With HIR saliency the per-frame salient count is < M: pick
        P_k at the observed peak so compaction is real yet exact — the
        full chunked session equals dense bit for bit."""
        dense = api.EPICCompressor(self._cfg(), models)
        ds, dt = jax.jit(dense.step)(dense.init(), stream)
        peak_sal = int(jnp.max(dt.n_salient))
        assert 0 < peak_sal < N_PATCHES, "seed must give partial saliency"
        comp = api.EPICCompressor(self._cfg(48, peak_sal), models)
        ss, st = jax.jit(comp.step)(comp.init(), stream)
        _tree_equal_bitwise(ds, ss)
        assert int(jnp.sum(st.n_patch_overflow)) == 0
        # Compaction really ran on processed frames.
        assert int(jnp.max(st.n_patch_checked)) == peak_sal
        _tree_equal_bitwise(
            dt._replace(n_patch_checked=jnp.zeros_like(dt.n_patch_checked)),
            st._replace(n_patch_checked=jnp.zeros_like(st.n_patch_checked)),
        )

    def test_chunked_ingest_bitwise_equals_one_shot(self, stream, models):
        comp = api.EPICCompressor(self._cfg(48, 4), models)
        one_state, _ = jax.jit(comp.step)(comp.init(), stream)
        step = jax.jit(comp.step)
        state = comp.init()
        for lo, hi in ((0, 8), (8, 16), (16, 24)):
            state, _ = step(
                state,
                api.SensorChunk(
                    stream.frames[lo:hi], stream.poses[lo:hi],
                    stream.gazes[lo:hi], stream.depth[lo:hi],
                ),
            )
        _tree_equal_bitwise(one_state, state)

    def test_dc_traffic_charges_measured_patch_reads(self, stream, models):
        """Dense runs' dc_traffic_bytes are unchanged by the new leaf;
        patch-compacted runs add the measured n_full x n_patch_checked
        bbox-row reads."""
        from repro.core import retained as ret

        cfg_d = self._cfg(48, 0)
        dense = api.EPICCompressor(cfg_d, models)
        _, dt = jax.jit(dense.step)(dense.init(), stream)
        ctr_d = P.stream_counters(cfg_d, dt)
        expect_dense = (
            int(jnp.sum(dt.n_full_checks)) * ret.patch_rgb_bytes(PATCH)
            + int(jnp.sum(dt.n_inserted)) * ret.dc_entry_bytes(PATCH)
        )
        assert ctr_d.dc_traffic_bytes == expect_dense

        cfg_s = self._cfg(48, 4)
        comp = api.EPICCompressor(cfg_s, models)
        _, st = jax.jit(comp.step)(comp.init(), stream)
        ctr_s = P.stream_counters(cfg_s, st)
        pair_reads = int(jnp.sum(st.n_full_checks * st.n_patch_checked))
        expect_sparse = (
            int(jnp.sum(st.n_full_checks)) * ret.patch_rgb_bytes(PATCH)
            + int(jnp.sum(st.n_inserted)) * ret.dc_entry_bytes(PATCH)
            + pair_reads * ret.bbox_row_bytes()
        )
        assert pair_reads > 0
        assert ctr_s.dc_traffic_bytes == expect_sparse


# ---------------------------------------------------------------------------
# Adaptive-K controller
# ---------------------------------------------------------------------------


class TestAdaptiveK:
    LADDER = (4, 8, 16, 48)

    @pytest.fixture(scope="class")
    def stream(self):
        scfg = SYN.StreamConfig(n_frames=32, hw=(FRAME, FRAME), n_obj=4)
        s, _ = SYN.generate_stream(jax.random.PRNGKey(5), scfg)
        return s

    def _cfg(self, prefilter_k=4):
        return P.EPICConfig(
            frame_hw=(FRAME, FRAME), patch=PATCH, capacity=48,
            tau=0.10, gamma=0.015, theta=8, window=16,
            prefilter_k=prefilter_k,
        )

    def _chunks(self, s, n=8):
        for lo in range(0, s.frames.shape[0], n):
            yield api.SensorChunk(
                s.frames[lo:lo + n], s.poses[lo:lo + n],
                s.gazes[lo:lo + n], s.depth[lo:lo + n],
            )

    def _run(self, s, **kw):
        comp = api.EPICCompressor(self._cfg(), k_ladder=self.LADDER, **kw)
        state = comp.init()
        for c in self._chunks(s):
            state, _ = comp.step(state, c)
        return comp, state

    def test_trajectory_deterministic(self, stream):
        c1, s1 = self._run(stream)
        c2, s2 = self._run(stream)
        assert c1.k_trajectory == c2.k_trajectory
        assert len(c1.k_trajectory) == 4
        _tree_equal_bitwise(s1, s2)
        # Rungs only move to adjacent ladder positions.
        pos = [self.LADDER.index(k) for k in c1.k_trajectory]
        assert all(abs(b - a) <= 1 for a, b in zip(pos, pos[1:]))

    def test_grows_on_overflow(self, stream):
        comp, _ = self._run(stream)
        # Starting at the bottom rung of a stream with >4 passing
        # entries per frame, the controller must climb.
        assert comp.k_trajectory[0] == 4
        assert comp.k_trajectory[-1] > 4

    def test_never_moves_is_bitwise_fixed_k(self, stream):
        fixed = api.EPICCompressor(self._cfg(48))
        step = jax.jit(fixed.step)
        fs = fixed.init()
        for c in self._chunks(stream):
            fs, _ = step(fs, c)
        adap = api.EPICCompressor(self._cfg(48), k_ladder=(48,))
        as_ = adap.init()
        for c in self._chunks(stream):
            as_, _ = adap.step(as_, c)
        assert adap.k_trajectory == [48] * 4
        _tree_equal_bitwise(fs, as_)

    def test_one_cached_step_per_visited_rung(self, stream):
        comp, _ = self._run(stream)
        assert set(comp._rung_steps) == set(comp.k_trajectory)

    def test_run_session_uses_host_step(self, stream):
        comp = api.EPICCompressor(self._cfg(), k_ladder=self.LADDER)
        chunk = api.SensorChunk(
            stream.frames, stream.poses, stream.gazes, stream.depth
        )
        state, _ = api.run_session(comp, chunk, chunk_size=8)
        assert len(comp.k_trajectory) == 4
        assert int(dcb.count_valid(state.buf)) > 0

    def test_ladder_validation(self):
        for bad in ((), (0, 4), (8, 8), (16, 8), ("a",)):
            with pytest.raises((ValueError, TypeError)):
                api.EPICCompressor(self._cfg(), k_ladder=bad)
        with pytest.raises(ValueError, match="not a rung"):
            api.EPICCompressor(self._cfg(5), k_ladder=(4, 8))
        # prefilter_k = 0 starts at the bottom rung.
        comp = api.EPICCompressor(self._cfg(0), k_ladder=(4, 8))
        assert comp.k_ladder == (4, 8)


# ---------------------------------------------------------------------------
# Fail-fast validation + graph memoization
# ---------------------------------------------------------------------------


class TestPatchKValidation:
    def test_negative_rejected(self):
        with pytest.raises(ValueError, match="patch_k"):
            tsrc_mod.TSRCConfig(patch_k=-1)
        with pytest.raises(ValueError, match="patch_k"):
            P.EPICConfig(patch_k=-3)

    def test_non_int_rejected(self):
        with pytest.raises(TypeError, match="patch_k"):
            tsrc_mod.TSRCConfig(patch_k=2.5)

    def test_replace_also_validates(self):
        with pytest.raises(ValueError, match="patch_k"):
            P.EPICConfig()._replace(patch_k=-2)
        assert P.EPICConfig()._replace(patch_k=8).patch_k == 8

    def test_zero_is_dense_default(self):
        assert tsrc_mod.TSRCConfig().patch_k == 0
        assert P.EPICConfig().patch_k == 0


class TestGraphMemoization:
    def test_same_cfg_and_models_hits_cache(self):
        cfg = P.EPICConfig(frame_hw=(FRAME, FRAME), patch=PATCH, capacity=8)
        models = P.EPICModels()
        g1 = P.build_epic_graph(cfg, models)
        g2 = P.build_epic_graph(cfg, models)
        assert g1 is g2

    def test_distinct_cfg_misses(self):
        models = P.EPICModels()
        g1 = P.build_epic_graph(
            P.EPICConfig(frame_hw=(FRAME, FRAME), patch=PATCH, capacity=8),
            models,
        )
        g2 = P.build_epic_graph(
            P.EPICConfig(frame_hw=(FRAME, FRAME), patch=PATCH, capacity=16),
            models,
        )
        assert g1 is not g2

    def test_distinct_models_identity_misses(self):
        cfg = P.EPICConfig(frame_hw=(FRAME, FRAME), patch=PATCH, capacity=8)
        g1 = P.build_epic_graph(cfg, P.EPICModels())
        g2 = P.build_epic_graph(cfg, P.EPICModels())
        assert g1 is not g2

    def test_eager_process_frame_reuses_graph(self):
        cfg = P.EPICConfig(frame_hw=(FRAME, FRAME), patch=PATCH, capacity=8)
        models = P.EPICModels()
        state = P.init_state(cfg)
        frame = jnp.zeros((FRAME, FRAME, 3))
        depth = jnp.ones((FRAME, FRAME))
        pose = jnp.eye(4)
        gaze = jnp.zeros((2,))
        before = P.build_epic_graph(cfg, models)
        s1, _ = P.process_frame(state, frame, pose, gaze, depth, models, cfg)
        s2, _ = P.process_frame(s1, frame, pose, gaze, depth, models, cfg)
        assert P.build_epic_graph(cfg, models) is before
        assert int(s2.t) == 2
