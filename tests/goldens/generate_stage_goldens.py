"""Generate the pre-refactor golden outputs for stage-graph parity tests.

Run ONCE against the monolithic (pre-stage-graph) pipeline and commit the
resulting ``stage_graph_golden.npz``; ``tests/test_stages.py`` then asserts
the stage-graph re-expression of EPIC and all four baselines reproduces
these outputs bit for bit.

  PYTHONPATH=src python tests/goldens/generate_stage_goldens.py

Refreshed with the sparse-TRD PR: all state leaves and match/insert stats
are unchanged bit for bit; only the EPIC ``n_bbox_checks``/``n_full_checks``
counters moved (now measured against the pre-insert buffer the TRD actually
ran on, instead of the permuted post-insert occupancy) and the
``n_prefilter_overflow`` leaf was appended (0 on the dense path pinned here).

Refreshed again with Sparse TRD v2: every pre-existing leaf is unchanged
bit for bit; only the ``n_patch_overflow`` / ``n_patch_checked`` counter
leaves were appended (both 0 on the dense path pinned here).
"""

import os

import jax
import numpy as np

from repro import api
from repro.core import hir
from repro.core import pipeline as P
from repro.data import synthetic as SYN

FRAME = 64
PATCH = 16
N_FRAMES = 40
HERE = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(HERE, "stage_graph_golden.npz")


def stream():
    scfg = SYN.StreamConfig(n_frames=N_FRAMES, hw=(FRAME, FRAME), n_obj=4)
    s, _ = SYN.generate_stream(jax.random.PRNGKey(0), scfg)
    return s


def epic_cfg():
    return P.EPICConfig(
        frame_hw=(FRAME, FRAME), patch=PATCH, capacity=32,
        tau=0.10, gamma=0.015, theta=8, window=16,
    )


def main():
    s = stream()
    chunk = api.SensorChunk(s.frames, s.poses, s.gazes, s.depth)
    out = {}

    def record(tag, state, stats):
        for i, leaf in enumerate(jax.tree.leaves(state)):
            out[f"{tag}/state/{i}"] = np.asarray(leaf)
        for i, leaf in enumerate(jax.tree.leaves(stats)):
            out[f"{tag}/stats/{i}"] = np.asarray(leaf)

    # EPIC, oracle mode (gt depth, all-salient).
    comp = api.get_compressor("epic")(epic_cfg())
    state, stats = jax.jit(comp.step)(comp.init(), chunk)
    record("epic_oracle", state, stats)

    # EPIC with a (randomly initialised) HIR saliency model — exercises
    # the saliency stage's learned path.
    models = P.EPICModels(
        depth_params=None,
        hir_params=hir.init_params(jax.random.PRNGKey(7)),
    )
    comp = api.get_compressor("epic")(epic_cfg(), models)
    state, stats = jax.jit(comp.step)(comp.init(), chunk)
    record("epic_hir", state, stats)

    # The four streaming baselines at a bounded budget (and FV unbounded).
    for name, budget in (("fv", -1), ("sd", 64), ("td", 64), ("gc", 64)):
        comp = api.get_compressor(name)(api.BaselineConfig(
            frame_hw=(FRAME, FRAME), patch=PATCH,
            budget_patches=budget, n_frames=N_FRAMES,
        ))
        state, stats = jax.jit(comp.step)(comp.init(), chunk)
        record(name, state, stats)

    np.savez_compressed(OUT, **out)
    print(f"wrote {OUT} ({os.path.getsize(OUT) / 1e6:.2f} MB, "
          f"{len(out)} arrays)")


if __name__ == "__main__":
    main()
