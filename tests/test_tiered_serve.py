"""Tiered-serving tests (`repro.serve.tiers` + the tiered
`StreamServer` mode): sub-pool bookkeeping, device-side migration and
swap bit-identity, speculative admission, the cost-model rung
scheduler's deterministic planning/coalescing, coalesced ``step_multi``
bit-identity, the single-sync multi-tier readback, and the acceptance
soak — a tiered pool-16 server under churn + migration stays bitwise
identical to the flat pool with zero post-warmup retraces."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import pipeline as P
from repro.data import synthetic as SYN
from repro.serve import (
    DispatchPlan,
    RungScheduler,
    ServerConfig,
    SlottedPool,
    StreamServer,
    TieredPool,
    validate_tiers,
)
from repro.serve import telemetry as TEL

FRAME = 64
PATCH = 16
CHUNK = 8


def _ecfg(**kw):
    base = dict(
        frame_hw=(FRAME, FRAME), patch=PATCH, capacity=32,
        tau=0.10, gamma=0.015, theta=8, window=16,
    )
    base.update(kw)
    return P.EPICConfig(**base)


def _stream(seed, n_frames=16, n_obj=4):
    scfg = SYN.StreamConfig(n_frames=n_frames, hw=(FRAME, FRAME), n_obj=n_obj)
    return SYN.generate_stream(jax.random.PRNGKey(seed), scfg)[0]


def _chunks(s, n=CHUNK):
    for lo in range(0, s.frames.shape[0], n):
        yield api.SensorChunk(
            s.frames[lo:lo + n], s.poses[lo:lo + n],
            s.gazes[lo:lo + n], s.depth[lo:lo + n],
        )


def _assert_tree_bitwise(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{msg} leaf {i}"
        )


def _batch(rows):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)


# ---------------------------------------------------------------------------
# TieredPool: bookkeeping, migration, swap, speculative admission
# ---------------------------------------------------------------------------


class TestTieredPool:
    def test_validation_and_addressing(self):
        with pytest.raises(ValueError, match="sum to"):
            validate_tiers((2, 4), 8)
        with pytest.raises(ValueError, match="positive"):
            validate_tiers((0, 8), 8)
        with pytest.raises(ValueError, match="positive"):
            validate_tiers((), 0)
        pool = TieredPool(api.EPICCompressor(_ecfg(capacity=8)), (2, 4))
        assert pool.capacity == 6 and pool.offsets == (0, 2)
        # admission defaults to the coldest tier with room
        assert pool.admit("a") == 2  # tier 1, local 0 -> global 2
        assert pool.admit("b", tier=0) == 0
        assert pool.locate("a") == (1, 0) and pool.locate("b") == (0, 0)
        assert pool.unpack_slot(5) == (1, 3)
        assert sorted(pool.live_sessions()) == ["a", "b"]
        assert pool.free_slots() == [1, 3, 4, 5]
        with pytest.raises(ValueError, match="already admitted"):
            pool.admit("a")
        for i in range(4):
            pool.admit(f"fill{i}")
        with pytest.raises(RuntimeError, match="pool full"):
            pool.admit("overflow")

    def test_migration_and_swap_preserve_state_bitwise(self):
        cfg = _ecfg(capacity=16)
        pool = TieredPool(api.EPICCompressor(cfg), (1, 2))
        pool.admit("x", tier=0)
        pool.admit("y", tier=1)
        zero = jax.tree.map(jnp.zeros_like, next(_chunks(_stream(0))))
        for ti, sid, seed in ((0, "x", 1), (1, "y", 2)):
            chunk = next(_chunks(_stream(seed)))
            rows = [zero] * pool.capacities[ti]
            rows[pool.locate(sid)[1]] = chunk
            pool.tiers[ti].step(_batch(rows))
        x_ref = jax.tree.map(np.asarray, pool.session_state("x"))
        y_ref = jax.tree.map(np.asarray, pool.session_state("y"))
        # migrate x down: state verbatim, source slot freed, dest
        # generation bumped
        gen_before = pool.generation_of(2)
        assert pool.migrate("x", 1) == 2
        assert pool.locate("x") == (1, 1)
        assert pool.generation_of(2) == gen_before + 1
        assert pool.tiers[0].free_slots() == [0]
        _assert_tree_bitwise(pool.session_state("x"), x_ref, "migrated x")
        with pytest.raises(ValueError, match="already in tier"):
            pool.migrate("x", 1)
        # swap x back up past y: both states move verbatim
        pool.admit("z", tier=0)
        pool.swap("z", "y")  # hot z <-> warm y
        _assert_tree_bitwise(pool.session_state("y"), y_ref, "swapped y")
        assert pool.locate("y") == (0, 0)
        with pytest.raises(ValueError, match="both in"):
            pool.swap("x", "z")
        assert pool.n_migrations == 1 and pool.n_swaps == 1

    def test_migrate_into_full_tier_refused(self):
        pool = TieredPool(api.EPICCompressor(_ecfg(capacity=8)), (1, 1))
        pool.admit("a", tier=0)
        pool.admit("b", tier=1)
        with pytest.raises(RuntimeError, match="full"):
            pool.migrate("b", 0)

    def test_speculative_admission_shares_one_fresh_image(self):
        """``compressor.init()`` runs exactly once per TieredPool —
        shared across every tier's admit scatter."""
        comp = api.EPICCompressor(_ecfg(capacity=8))
        calls = []
        real_init = comp.init

        class Counting:
            def __getattr__(self, name):
                return getattr(comp, name)

            def init(self):
                calls.append(1)
                return real_init()

        pool = TieredPool(Counting(), (2, 4))
        pool.prewarm()
        for i in range(6):
            pool.admit(f"s{i}")
        for i in range(6):
            pool.evict_session(f"s{i}")
        assert len(calls) == 1
        assert all(t._fresh is pool._fresh for t in pool.tiers)

    def test_prewarm_compiles_lifecycle_then_churn_never_compiles(self):
        pool = TieredPool(api.EPICCompressor(_ecfg(capacity=8)), (1, 2))
        pool.prewarm()
        assert pool.n_migrations == 0 and pool.n_swaps == 0
        assert pool.free_slots() == [0, 1, 2]
        sizes = {
            "admit": [int(t._admit_fn._cache_size()) for t in pool.tiers],
            "evict": [int(t._evict_fn._cache_size()) for t in pool.tiers],
            "migrate": {
                k: int(f._cache_size())
                for k, f in pool._migrate_fns.items()
            },
            "swap": {
                k: int(f._cache_size()) for k, f in pool._swap_fns.items()
            },
        }
        assert sizes["migrate"] == {(0, 1): 1, (1, 0): 1}
        assert sizes["swap"] == {(0, 1): 1}
        # real churn + migration after prewarm: cache sizes frozen
        pool.admit("a", tier=0)
        pool.admit("b")
        pool.migrate("a", 1)
        pool.migrate("a", 0)
        pool.swap("a", "b")
        pool.evict_session("a"), pool.evict_session("b")
        assert sizes == {
            "admit": [int(t._admit_fn._cache_size()) for t in pool.tiers],
            "evict": [int(t._evict_fn._cache_size()) for t in pool.tiers],
            "migrate": {
                k: int(f._cache_size())
                for k, f in pool._migrate_fns.items()
            },
            "swap": {
                k: int(f._cache_size()) for k, f in pool._swap_fns.items()
            },
        }


# ---------------------------------------------------------------------------
# RungScheduler: deterministic planning + cost model
# ---------------------------------------------------------------------------


class TestRungScheduler:
    def test_plan_orders_most_expensive_first(self):
        sched = RungScheduler()
        plans = sched.plan({(0, 4): ["a"], (0, 16): ["b"], (1, 8): ["c"]})
        # un-measured: the K-proportional prior orders 16 > 8 > 4
        assert [p.key for p in plans] == [16, 8, 4]
        assert plans[0] == DispatchPlan(0, (16,), (("b",),))
        # a measured cost overrides the prior
        sched.observe_tick([4], 5.0)
        plans = sched.plan({(0, 4): ["a"], (0, 16): ["b"]})
        assert [p.key for p in plans] == [4, 16]

    def test_observe_only_attributes_single_dispatch_ticks(self):
        sched = RungScheduler(ema_alpha=0.5)
        sched.observe_tick([4, 8], 9.0)  # ambiguous: ignored
        assert sched.cost_estimates() == {}
        sched.observe_tick([4], 2.0)
        sched.observe_tick([4], 4.0)
        assert sched.cost_estimates() == {4: 3.0}
        # tuple keys estimate as the sum of their parts
        assert sched.estimate((4, 8)) == pytest.approx(3.0 + 8e-6)

    def test_coalescing_is_deterministic_and_backlog_gated(self):
        sched = RungScheduler(coalesce=True, coalesce_backlog=0)
        groups = {(0, 8): ["b"], (0, 4): ["a"], (0, 16): ["c"]}
        plans = sched.plan(dict(groups), backlog=0)
        # ascending adjacent pairing: (4, 8) merged, 16 alone — never
        # cost-dependent, so the compiled-key set is traffic-only
        assert sorted(p.rungs for p in plans) == [(4, 8), (16,)]
        assert sched.n_coalesced == 1
        merged = next(p for p in plans if p.rungs == (4, 8))
        assert merged.sids == (("a",), ("b",)) and merged.key == (4, 8)
        # backlog above the gate: no coalescing (compute-bound tick)
        plans = sched.plan(dict(groups), backlog=3)
        assert sorted(p.rungs for p in plans) == [(4,), (8,), (16,)]
        # identical traffic -> identical plan keys, regardless of
        # measured costs in between
        sched.observe_tick([16], 0.5)
        again = sched.plan(dict(groups), backlog=0)
        assert sorted(p.rungs for p in again) == [(4, 8), (16,)]

    def test_coalescing_keeps_tiers_separate(self):
        sched = RungScheduler(coalesce=True)
        plans = sched.plan({(0, 4): ["a"], (1, 8): ["b"]}, backlog=0)
        assert sorted((p.tier, p.rungs) for p in plans) == [
            (0, (4,)), (1, (8,)),
        ]
        assert sched.n_coalesced == 0

    def test_validation(self):
        with pytest.raises(ValueError, match="ema_alpha"):
            RungScheduler(ema_alpha=0.0)


# ---------------------------------------------------------------------------
# Coalesced step_multi: bitwise vs sequential per-rung dispatches
# ---------------------------------------------------------------------------


class TestStepMulti:
    def test_step_multi_bitwise_equals_sequential_dispatches(self):
        cfg = _ecfg(capacity=16, prefilter_k=4)
        comps = {
            k: api.EPICCompressor(cfg._replace(prefilter_k=k))
            for k in (4, 16)
        }
        streams = [_stream(20 + i) for i in range(4)]
        pools = [
            SlottedPool(api.EPICCompressor(cfg), 4) for _ in range(2)
        ]
        for pool in pools:
            for i in range(4):
                pool.admit(i)
        masks = jnp.stack([
            jnp.array([True, True, False, False]),
            jnp.array([False, False, True, True]),
        ])
        for step_i in range(2):
            batch = _batch(
                [list(_chunks(s))[step_i] for s in streams]
            )
            # sequential: one masked dispatch per rung
            s_a = pools[0].step(
                batch, mask=masks[0], step_fn=comps[4].step, key=4
            )
            s_b = pools[0].step(
                batch, mask=masks[1], step_fn=comps[16].step, key=16
            )
            seq_stats = jax.tree.map(
                lambda a, b: a | b if a.dtype == bool else a + b, s_a, s_b
            )
            # coalesced: both rungs in one dispatch
            multi_stats = pools[1].step_multi(
                batch, masks, [comps[4].step, comps[16].step], key=(4, 16)
            )
            _assert_tree_bitwise(multi_stats, seq_stats, "stats")
        _assert_tree_bitwise(
            pools[1].states.sessions, pools[0].states.sessions, "states"
        )
        assert pools[1].step_cache_sizes() == {(4, 16): 1}


# ---------------------------------------------------------------------------
# Telemetry: multi-tier readback in one device_get
# ---------------------------------------------------------------------------


class TestMultiTierReadback:
    def test_multi_tier_tick_readback_single_device_get(self, monkeypatch):
        cfg = _ecfg(capacity=16)
        comp = api.EPICCompressor(cfg)
        parts = []
        for cap, seeds in ((2, (30, 31)), (3, (32,))):
            pool = SlottedPool(comp, cap)
            zero = jax.tree.map(
                jnp.zeros_like, next(_chunks(_stream(0)))
            )
            rows = [zero] * cap
            for i, seed in enumerate(seeds):
                pool.admit(f"t{cap}s{i}")
                rows[i] = next(_chunks(_stream(seed)))
            parts.append(pool.step(_batch(rows)))

        calls = []
        real_get = jax.device_get

        def counting_get(x):
            calls.append(1)
            return real_get(x)

        monkeypatch.setattr(TEL.jax, "device_get", counting_get)
        rb = TEL.tick_readback(parts)
        assert len(calls) == 1
        # rows concatenate in argument order: 2 + 3 slots
        assert rb.processed.shape == (5,)
        solo = [TEL.tick_readback(p) for p in parts]
        np.testing.assert_array_equal(
            rb.processed,
            np.concatenate([s.processed for s in solo]),
        )
        np.testing.assert_array_equal(
            rb.buffer_valid,
            np.concatenate([s.buffer_valid for s in solo]),
        )
        with pytest.raises(ValueError, match="at least one"):
            TEL.tick_readback([])


# ---------------------------------------------------------------------------
# Tiered StreamServer: facade behaviour + rebalancing
# ---------------------------------------------------------------------------


class TestTieredServer:
    def _servers(self, ladder=(4, 8, 16), **tiered_kw):
        cfg = _ecfg(capacity=48, prefilter_k=4)
        base = dict(capacity=8, chunk_frames=CHUNK, k_ladder=ladder)
        flat = StreamServer(api.EPICCompressor(cfg), ServerConfig(**base))
        tiered_kw = dict(
            dict(tiers=(2, 6), demote_idle_frames=2 * CHUNK, prewarm=True),
            **tiered_kw,
        )
        tiered = StreamServer(
            api.EPICCompressor(cfg), ServerConfig(**base, **tiered_kw)
        )
        return cfg, flat, tiered

    def test_validation(self):
        cfg = _ecfg(capacity=16)
        with pytest.raises(ValueError, match="sum to"):
            StreamServer(
                api.EPICCompressor(cfg),
                ServerConfig(capacity=8, tiers=(2, 2)),
            )
        with pytest.raises(ValueError, match="arrival_alpha"):
            StreamServer(
                api.EPICCompressor(cfg),
                ServerConfig(capacity=8, tiers=(2, 6), arrival_alpha=0.0),
            )

    def test_idle_demotes_active_promotes(self):
        _, _, srv = self._servers(ladder=None)
        for i in range(4):
            srv.admit(f"s{i}")
        # new streams land in the cold tier
        assert all(srv.telemetry(f"s{i}").tier == 1 for i in range(4))
        feeds = {
            f"s{i}": list(_chunks(_stream(40 + i, n_frames=64)))
            for i in range(2)
        }
        for t in range(8):
            for sid, chunks in feeds.items():
                srv.submit(sid, chunks[t])
            srv.tick()
        # the two active streams earned the (size-2) hot tier; the
        # idlers stayed cold
        assert {srv.telemetry(f"s{i}").tier for i in range(2)} == {0}
        assert {srv.telemetry(f"s{i}").tier for i in range(2, 4)} == {1}
        assert srv.telemetry("s0").n_migrations >= 1
        # starve the hot pair -> they demote back to cold
        for _ in range(4):
            srv.tick()
        assert {srv.telemetry(f"s{i}").tier for i in range(2)} == {1}
        assert srv.server_counters()["n_migrations"] >= 4

    def test_tiered_counters_and_cache_keys(self):
        _, _, srv = self._servers(ladder=None)
        srv.admit("a")
        for c in _chunks(_stream(5)):
            srv.submit("a", c)
            srv.tick()
        c = srv.server_counters()
        assert c["frames_served"] == 16 and c["n_dispatches"] == 2
        # chunk 1 stepped in the cold tier; the arrival EMA then earned
        # promotion, so chunk 2 stepped hot — keys are (tier, variant),
        # one compile each
        assert srv.step_cache_sizes() == {(1, None): 1, (0, None): 1}
        assert srv.telemetry("a").tier == 0

    def test_soak_tiered_bitwise_flat_with_churn_and_migration(self):
        """Acceptance: a tiered pool under churn + migration serves
        every stream bitwise identically (state and k_trajectory) to
        the flat pool, with zero post-warmup retraces."""
        cfg, flat, tiered = self._servers(coalesce_rungs=True)
        feeds = {
            f"s{i}": list(_chunks(_stream(
                60 + i, n_frames=48, n_obj=1 + (i % 3) * 2
            )))
            for i in range(5)
        }
        n = 6  # chunks per stream

        def run(srv):
            for sid in feeds:
                srv.admit(sid)
            # phase 1: s0/s1 stream steadily (earn the hot tier),
            # s2 idles mid-run (demotes), s3 streams, s4 idle
            for t in range(4):
                for i in (0, 1, 3):
                    srv.submit(f"s{i}", feeds[f"s{i}"][t])
                if t < 2:
                    srv.submit("s2", feeds["s2"][t])
                srv.tick()
            # churn: close s4, admit a late joiner on s0's feed tail
            srv.close("s4")
            srv.admit("late")
            for t in range(4, n):
                for i in (0, 1, 2, 3):
                    srv.submit(f"s{i}", feeds[f"s{i}"][t - (2 if i == 2 else 0)])
                srv.submit("late", feeds["s0"][t])
                srv.tick()
            # ragged tail: idle ticks (tiered side demotes everyone)
            for _ in range(5):
                srv.tick()

        run(flat)
        run(tiered)
        warm_sizes = dict(tiered.step_cache_sizes())
        # tier migration genuinely happened
        assert tiered.server_counters()["n_migrations"] >= 2
        # more traffic after warmup: replay the tail chunks via fresh
        # sessions to confirm the cache set is closed under more churn
        for srv in (flat, tiered):
            srv.admit("tail")
            for c in feeds["s1"][:2]:
                srv.submit("tail", c)
                srv.tick()
        for sid in tiered.live_sessions:
            _assert_tree_bitwise(
                tiered.state(sid), flat.state(sid), sid
            )
            assert (
                tiered.telemetry(sid).k_trajectory
                == flat.telemetry(sid).k_trajectory
            ), sid
        end_sizes = tiered.step_cache_sizes()
        for k, size in end_sizes.items():
            assert size == 1, (k, end_sizes)
        for k, size in warm_sizes.items():
            assert end_sizes[k] == size
