"""Per-architecture smoke tests: reduced config, one forward + one train
step + one prefill->decode handoff on CPU; asserts shapes and finiteness.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config, get_shapes
from repro.configs.base import ShapeSpec
from repro.models import build_model

B, S = 2, 32


def _smoke_shape(arch_id: str) -> ShapeSpec:
    return ShapeSpec("smoke", "train", S, B)


def _batch(model, key):
    cfg = model.cfg
    spec = model.batch_spec(_smoke_shape(cfg.name))
    batch = {}
    for name, sds in spec.items():
        if sds.dtype == jnp.int32:
            batch[name] = jax.random.randint(key, sds.shape, 0, cfg.vocab)
        else:
            batch[name] = jax.random.normal(key, sds.shape, sds.dtype) * 0.1
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


def test_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(model, jax.random.PRNGKey(1))

    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite logits"

    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0


def test_train_step_improves(arch):
    """Two SGD steps reduce the loss (learning signal flows end-to-end)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(model, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(model.loss_fn)(p, batch)
        p = jax.tree.map(
            lambda w, gw: w - 0.3 * gw.astype(w.dtype), p, g
        )
        return p, loss

    losses = []
    for _ in range(3):
        params, loss = step(params)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_decode_matches_forward(arch):
    """prefill + decode_step logits agree with the full forward pass.

    fp32 cache isolates the *math* equivalence (absorbed-MLA, windowed
    attention, recurrent states) from bf16 cache rounding, which over many
    layers exceeds any usable tolerance without indicating a bug.
    """
    cfg = get_smoke_config(arch).replace(cache_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(model, jax.random.PRNGKey(1))

    full = jax.jit(model.forward)(params, batch)  # (B,S,V)

    prefix = {k: (v[:, : S - 1] if k == "tokens" else v)
              for k, v in batch.items()}
    out = jax.jit(model.prefill)(params, prefix)
    logits_p, state = out
    if logits_p is not None:  # encdec prefill returns cache only
        np.testing.assert_allclose(
            np.asarray(logits_p[:, -1]),
            np.asarray(full[:, S - 2]),
            rtol=2e-2,
            atol=2e-2,
        )

    if cfg.family == "encdec":
        # decode from scratch: feed tokens 0..S-2, compare next-token logits
        pos = jnp.zeros((), jnp.int32)
        dec = jax.jit(model.decode_step)
        for t in range(S - 1):
            tok = batch["tokens"][:, t : t + 1]
            logits_d, state = dec(params, state, tok, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]),
            np.asarray(full[:, S - 2]),
            rtol=2e-2,
            atol=2e-2,
        )
        return

    # continue one token with the serve state from prefill
    tok = batch["tokens"][:, S - 1 : S]
    if cfg.family in ("dense", "moe_mla", "vlm"):
        # pad the prefill cache out to S so the decode write fits
        def pad(a):
            if a.ndim >= 2 and a.shape[-2] == S - 1:
                widths = [(0, 0)] * a.ndim
                widths[-2] = (0, 1)
                return jnp.pad(a, widths)
            return a

        state = jax.tree.map(pad, state)
    logits_d, _ = jax.jit(model.decode_step)(
        params, state, tok, jnp.int32(S - 1)
    )
    np.testing.assert_allclose(
        np.asarray(logits_d[:, -1]),
        np.asarray(full[:, S - 1]),
        rtol=2e-2,
        atol=2e-2,
    )


def test_zamba2_windowed_serving_self_consistent():
    """Windowed (long-context) serving: prefill+decode == pure decode.

    With attn_window < context, the modular KV cache from ``prefill`` must
    hand off to ``decode_step`` exactly as if every token had been decoded
    one at a time (the long_500k serving mode).
    """
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("zamba2-2.7b").replace(
        cache_dtype="float32", attn_window=8
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    # path A: prefill S-1 tokens, decode the last
    _, state = model.prefill(params, {"tokens": toks[:, : S - 1]})
    la, _ = model.decode_step(
        params, state, toks[:, S - 1 :], jnp.int32(S - 1)
    )

    # path B: decode every token from scratch
    state = model.init_serve(B, S)
    dec = jax.jit(model.decode_step)
    for t in range(S):
        lb, state = dec(params, state, toks[:, t : t + 1], jnp.int32(t))

    np.testing.assert_allclose(
        np.asarray(la[:, -1]), np.asarray(lb[:, -1]), rtol=1e-4, atol=1e-4
    )


def test_full_config_consistency(arch):
    """The FULL config matches the published spec table (no allocation)."""
    from repro.configs import get_config

    cfg = get_config(arch)
    spec = {
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "seamless-m4t-large-v2": (48, 1024, 16, 16, 8192, 256206),
    }[arch]
    got = (
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.d_ff,
        cfg.vocab,
    )
    assert got == spec, (got, spec)
    shapes = get_shapes(arch)
    assert {s.name for s in shapes} == {
        "train_4k", "prefill_32k", "decode_32k", "long_500k",
    }
    long = next(s for s in shapes if s.name == "long_500k")
    if arch in ("rwkv6-3b", "zamba2-2.7b"):
        assert long.skip is None
    else:
        assert long.skip is not None
