"""Tests for optimizer, checkpointing, and fault-tolerant runtime."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.optim import adamw, compress, schedule
from repro.runtime import fault


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _quad_problem():
    key = jax.random.PRNGKey(0)
    target = {"a": jax.random.normal(key, (8, 8)), "b": jnp.ones((8,))}
    params = jax.tree.map(jnp.zeros_like, target)

    def loss_fn(p):
        return sum(
            jnp.sum(jnp.square(p[k] - target[k])) for k in ("a", "b")
        )

    return params, loss_fn


def test_adamw_converges_quadratic():
    params, loss_fn = _quad_problem()
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0)
    state = adamw.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(loss_fn)(p)
        p, s, gn = adamw.update(g, s, p, cfg)
        return p, s, gn

    l0 = float(loss_fn(params))
    for _ in range(200):
        params, state, _ = step(params, state)
    assert float(loss_fn(params)) < 1e-2 * l0


def test_adamw_clip_and_dtype():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    g = {"w": jnp.full((4,), 100.0, jnp.bfloat16)}
    cfg = adamw.AdamWConfig(lr=1e-2, clip_norm=1.0, weight_decay=0.0)
    st = adamw.init(params)
    p2, st2, gn = adamw.update(g, st, params, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert float(gn) == pytest.approx(200.0, rel=1e-2)  # pre-clip norm
    assert st2.mu["w"].dtype == jnp.float32


def test_warmup_cosine_shape():
    lr = schedule.warmup_cosine(
        jnp.arange(100), peak_lr=1.0, warmup_steps=10, total_steps=100
    )
    assert float(lr[0]) == 0.0
    assert float(lr[10]) == pytest.approx(1.0, abs=1e-6)
    assert float(lr[99]) < 0.2
    assert bool(jnp.all(jnp.diff(lr[:10]) > 0))


# ---------------------------------------------------------------------------
# EF-int8 compression
# ---------------------------------------------------------------------------


def test_ef_int8_tracks_uncompressed_sgd():
    """Error feedback: compressed SGD converges to the same optimum."""
    params, loss_fn = _quad_problem()
    pc = jax.tree.map(jnp.copy, params)
    ef = compress.init(params)
    lr = 0.05
    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        gq = jax.grad(loss_fn)(pc)
        q, scales, ef = compress.compress(gq, ef)
        gd = compress.decompress(q, scales)
        pc = jax.tree.map(lambda p, gg: p - lr * gg, pc, gd)
    lf = float(loss_fn(params))
    lc = float(loss_fn(pc))
    assert lc < 1e-3, lc
    assert abs(lc - lf) < 1e-3


def test_ef_int8_payload_dtype():
    g = {"w": jnp.linspace(-1, 1, 64)}
    q, scales, ef = compress.compress(g, compress.init(g))
    assert q["w"].dtype == jnp.int8
    rec = compress.decompress(q, scales)
    np.testing.assert_allclose(rec["w"], g["w"], atol=2.0 / 127.0)


# ---------------------------------------------------------------------------
# Checkpoint store
# ---------------------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(k, (16, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 3, t)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t
    )
    out, step = store.restore(str(tmp_path), like)
    assert step == 3
    assert out["layers"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["layers"]["w"]), np.asarray(t["layers"]["w"])
    )


def test_checkpoint_atomicity_incomplete_ignored(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 1, t)
    # simulate a crashed save: step dir without manifest
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "shard_0.npz").write_bytes(b"garbage")
    assert store.latest_step(str(tmp_path)) == 1


def test_checkpoint_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        store.save(str(tmp_path), s, t)
    store.gc_old(str(tmp_path), keep=2)
    assert store.latest_step(str(tmp_path)) == 5
    assert sorted(os.listdir(tmp_path))[:1] == ["step_00000004"]


def test_checkpoint_async(tmp_path):
    saver = store.AsyncSaver()
    t = _tree()
    saver.save(str(tmp_path), 11, t)
    saver.wait()
    assert store.latest_step(str(tmp_path)) == 11


def test_checkpoint_shape_mismatch_raises(tmp_path):
    store.save(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        store.restore(str(tmp_path), {"w": jnp.zeros((5,))})


# ---------------------------------------------------------------------------
# Fault-tolerant loop
# ---------------------------------------------------------------------------


def test_fault_loop_bit_exact_recovery(tmp_path):
    """A run with injected failures ends bit-identical to a clean run."""

    def make_batch(step):
        return jax.random.normal(jax.random.PRNGKey(step), (4,))

    def make_step(injector=None):
        def step_fn(state, batch):
            if injector is not None:
                injector.maybe_fail(int(state["i"]))
            return (
                {"x": state["x"] + jnp.sum(batch), "i": state["i"] + 1},
                {},
            )

        return step_fn

    init = {"x": jnp.zeros(()), "i": jnp.int32(0)}

    clean = fault.FaultTolerantLoop(
        fault.LoopConfig(str(tmp_path / "clean"), ckpt_every=3),
        make_step(),
        make_batch,
    ).run(init, 10)

    inj = fault.FailureInjector([4, 8])
    cfg = fault.LoopConfig(str(tmp_path / "faulty"), ckpt_every=3)
    loop = fault.FaultTolerantLoop(cfg, make_step(inj), make_batch)
    faulty = loop.run(init, 10)

    assert loop.stats.restarts == 2
    np.testing.assert_array_equal(np.asarray(clean["x"]), np.asarray(faulty["x"]))
    assert int(faulty["i"]) == 10


def test_fault_loop_gives_up_after_max_restarts(tmp_path):
    def step_fn(state, batch):
        raise fault.WorkerFailure("always")

    loop = fault.FaultTolerantLoop(
        fault.LoopConfig(str(tmp_path), max_restarts=2),
        step_fn,
        lambda s: None,
    )
    with pytest.raises(fault.WorkerFailure):
        loop.run({"x": jnp.zeros(())}, 3)


def test_straggler_detection(tmp_path):
    import time as _time

    def step_fn(state, batch):
        if int(state["i"]) == 5:
            _time.sleep(0.2)
        else:
            _time.sleep(0.01)
        return {"i": state["i"] + 1}, {}

    seen = []
    loop = fault.FaultTolerantLoop(
        fault.LoopConfig(str(tmp_path), straggler_factor=4.0),
        step_fn,
        lambda s: None,
        on_straggler=lambda step, ratio: seen.append((step, ratio)),
    )
    loop.run({"i": jnp.int32(0)}, 8)
    assert loop.stats.stragglers >= 1
    assert seen and seen[0][1] > 4.0


# ---------------------------------------------------------------------------
# Crash-mid-save recovery: damaged checkpoints fall back, debris is cleaned
# ---------------------------------------------------------------------------


def _damage_truncate_shard(d):
    p = d / "shard_0.npz"
    p.write_bytes(p.read_bytes()[: max(1, p.stat().st_size // 2)])


def _damage_delete_shard(d):
    (d / "shard_0.npz").unlink()


def _damage_delete_manifest(d):
    (d / "manifest.json").unlink()


def _damage_corrupt_manifest(d):
    (d / "manifest.json").write_text("{not json")


@pytest.mark.parametrize(
    "damage",
    [
        _damage_truncate_shard,
        _damage_delete_shard,
        _damage_delete_manifest,
        _damage_corrupt_manifest,
    ],
)
def test_restore_falls_back_past_damaged_newest(tmp_path, damage):
    """A crash that leaves the newest step unreadable must not take the
    previous good checkpoint down with it."""
    t1, t2 = _tree(1), _tree(2)
    store.save(str(tmp_path), 1, t1)
    store.save(str(tmp_path), 2, t2)
    damage(tmp_path / "step_00000002")
    out, step = store.restore(str(tmp_path), _tree(0))
    assert step == 1
    np.testing.assert_array_equal(
        np.asarray(out["layers"]["w"]), np.asarray(t1["layers"]["w"])
    )


def test_restore_explicit_step_never_falls_back(tmp_path):
    store.save(str(tmp_path), 1, _tree(1))
    store.save(str(tmp_path), 2, _tree(2))
    _damage_delete_shard(tmp_path / "step_00000002")
    with pytest.raises(FileNotFoundError):
        store.restore(str(tmp_path), _tree(0), step=2)


def test_restore_tolerates_gc_race(tmp_path, monkeypatch):
    """The newest step vanishing between selection and load (a
    concurrent gc_old / two processes racing) falls back instead of
    crashing the restore."""
    store.save(str(tmp_path), 1, _tree(1))
    store.save(str(tmp_path), 2, _tree(2))
    real = store._load_step
    calls = []

    def racy(directory, step, like, shardings):
        if not calls:
            calls.append(step)
            import shutil

            shutil.rmtree(tmp_path / "step_00000002")
        return real(directory, step, like, shardings)

    monkeypatch.setattr(store, "_load_step", racy)
    out, step = store.restore(str(tmp_path), _tree(0))
    assert step == 1 and calls == [2]


def test_restore_all_damaged_reraises(tmp_path):
    store.save(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        # shape mismatch is "damage" for fallback purposes, but with no
        # older step to fall back to the error must surface, not be
        # swallowed into a FileNotFoundError
        store.restore(str(tmp_path), {"w": jnp.zeros((5,))})


def test_save_cleans_stale_tmp_dirs(tmp_path):
    """Debris from a crashed save (rename never ran) is swept by the
    next successful save in the same directory."""
    stale = tmp_path / "step_00000007.tmp"
    stale.mkdir()
    (stale / "shard_0.npz").write_bytes(b"partial")
    store.save(str(tmp_path), 9, _tree())
    assert not stale.exists()
    assert store.latest_step(str(tmp_path)) == 9


def test_async_saver_surfaces_background_errors(tmp_path):
    """A write failure on the saver thread re-raises on the next
    save()/wait() instead of silently ending persistence."""
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("file where the ckpt dir should go")
    saver = store.AsyncSaver()
    saver.save(str(blocker), 1, _tree())
    with pytest.raises(OSError):
        saver.wait()
    # the error is consumed: the saver remains usable
    saver.save(str(tmp_path), 2, _tree())
    saver.wait()
    assert store.latest_step(str(tmp_path)) == 2


def test_failure_injector_hashable_labels():
    inj = fault.FailureInjector([("mid_tick", 3), "mid_save"])
    inj.maybe_fail(("mid_tick", 1))  # not armed
    with pytest.raises(fault.WorkerFailure):
        inj.maybe_fail(("mid_tick", 3))
    inj.maybe_fail(("mid_tick", 3))  # fires once, replay passes
    with pytest.raises(fault.WorkerFailure):
        inj.maybe_fail("mid_save")
    assert inj.calls == 4
