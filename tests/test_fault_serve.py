"""Fault-tolerant serving: live-slot checkpoint/restore + crash soak.

Covers the `repro.serve.checkpoint` contract — snapshot a running
StreamServer (device slot states, generations, controllers, queued
chunks, scheduler costs, wire cursors), restore into a *fresh* process,
and resume serving bit-identically — plus the kill→restore→replay soak
with deterministic FailureInjector crash points (mid-tick, mid-save,
mid-migration, mid-wire-frame).  The soak's acceptance bar: per-stream
outputs and ``k_trajectory`` bitwise equal to an uninterrupted run, and
zero post-restore retraces (every pool step variant compiled exactly
once in the restored process)."""

import os

import jax
import numpy as np
import pytest

from repro import api
from repro.checkpoint import store
from repro.core import pipeline as P
from repro.data import synthetic as SYN
from repro.runtime import fault
from repro.serve import ServerConfig, StreamServer
from repro.serve.checkpoint import (
    SERVE_SCHEMA,
    ServeCheckpointer,
    restore_server,
    save_server,
    snapshot_server,
)
from repro.serve.slots import StaleSlotError
from repro.wire import codec
from repro.wire.server import IngestServer, Loopback, ResumableSession

FRAME = 64
PATCH = 16
CHUNK = 8
LADDER = (8, 16, 32)
N_STREAMS = 3
N_ROUNDS = 5


def _ecfg(**kw):
    base = dict(
        frame_hw=(FRAME, FRAME), patch=PATCH, capacity=32,
        tau=0.10, gamma=0.015, theta=8, window=16,
    )
    base.update(kw)
    return P.EPICConfig(**base)


def _comp(k=8):
    return api.EPICCompressor(_ecfg(prefilter_k=k))


def _chunks(seed, n_frames=48):
    scfg = SYN.StreamConfig(n_frames=n_frames, hw=(FRAME, FRAME), n_obj=4)
    s, _ = SYN.generate_stream(jax.random.PRNGKey(seed), scfg)
    stream = api.SensorChunk(s.frames, s.poses, s.gazes, s.depth)
    return list(api.iter_chunks(stream, CHUNK, remainder="drop"))


def _server_cfg(tiers=None, k_ladder=LADDER, **kw):
    return ServerConfig(
        capacity=4, chunk_frames=CHUNK, queue_depth=2,
        k_ladder=k_ladder, tiers=tiers, **kw,
    )


def _assert_tree_bitwise(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{msg} leaf {i}"
        )


# ---------------------------------------------------------------------------
# Snapshot / restore roundtrips


class TestSnapshotRestore:
    @pytest.mark.parametrize(
        "tiers,k_ladder",
        [(None, None), (None, LADDER), ((2, 2), LADDER)],
        ids=["flat", "adaptive", "tiered"],
    )
    def test_roundtrip_bitwise(self, tmp_path, tiers, k_ladder):
        """Save a live server mid-run (queued chunks on board), restore
        fresh, finish serving: states + k_trajectory bitwise equal to
        the uninterrupted server."""
        chunks = {sid: _chunks(sid) for sid in (1, 2, 3)}

        def build():
            srv = StreamServer(
                _comp(8 if k_ladder else 0),
                _server_cfg(tiers=tiers, k_ladder=k_ladder),
            )
            for sid in chunks:
                srv.admit(sid)
            for i in range(2):
                for sid in chunks:
                    assert srv.submit(sid, chunks[sid][i])
                srv.tick()
            # leave one chunk pending in each queue at snapshot time
            for sid in chunks:
                assert srv.submit(sid, chunks[sid][2])
            return srv

        ref = build()
        ref.tick()
        for sid in chunks:
            assert ref.submit(sid, chunks[sid][3])
        ref.tick()

        srv = build()
        save_server(str(tmp_path), srv.n_ticks, srv)
        srv2, ingest, step = restore_server(
            str(tmp_path), _comp(8 if k_ladder else 0)
        )
        assert step == 2 and ingest is None
        assert srv2.live_sessions == list(chunks)
        assert all(len(q) == 1 for q in srv2._queues.values())
        srv2.tick()  # serves the restored queue contents
        for sid in chunks:
            assert srv2.submit(sid, chunks[sid][3])
        srv2.tick()

        for sid in chunks:
            _assert_tree_bitwise(
                ref.state(sid), srv2.state(sid), f"stream {sid}"
            )
            assert (
                ref.telemetry(sid).k_trajectory
                == srv2.telemetry(sid).k_trajectory
            )
        assert srv2.n_ticks == ref.n_ticks
        # one compile per variant in the restored process: restore
        # itself never traces a pool program
        assert all(v == 1 for v in srv2.step_cache_sizes().values())

    def test_counters_and_evicted_survive(self, tmp_path):
        srv = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        chunks = _chunks(5)
        srv.admit(1)
        srv.admit(2)
        for i in range(2):
            srv.submit(1, chunks[i])
            srv.tick()
        srv.close(2)
        save_server(str(tmp_path), srv.n_ticks, srv)
        srv2, _, _ = restore_server(str(tmp_path), _comp(0))
        assert srv2.server_counters() == srv.server_counters()
        assert [t.session_id for t in srv2.evicted] == [2]
        assert srv2._sched.cost_estimates() == srv._sched.cost_estimates()

    def test_restore_into_provided_prewarmed_server(self, tmp_path):
        cfg = _server_cfg(k_ladder=None, prewarm=True)
        srv = StreamServer(_comp(0), cfg)
        chunks = _chunks(7)
        srv.admit(1)
        srv.submit(1, chunks[0])
        srv.tick()
        save_server(str(tmp_path), srv.n_ticks, srv)
        target = StreamServer(_comp(0), cfg)
        srv2, _, _ = restore_server(str(tmp_path), _comp(0), server=target)
        assert srv2 is target
        _assert_tree_bitwise(srv.state(1), srv2.state(1))

    def test_provided_server_fences(self, tmp_path):
        srv = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        srv.admit(1)
        save_server(str(tmp_path), 0, srv)
        other_cfg = StreamServer(
            _comp(0),
            _server_cfg(k_ladder=None)._replace(queue_depth=3),
        )
        with pytest.raises(ValueError, match="config"):
            restore_server(str(tmp_path), _comp(0), server=other_cfg)
        busy = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        busy.admit(9)
        with pytest.raises(ValueError, match="live sessions"):
            restore_server(str(tmp_path), _comp(0), server=busy)

    def test_compressor_fence(self, tmp_path):
        srv = StreamServer(_comp(8), _server_cfg())
        srv.admit(1)
        save_server(str(tmp_path), 0, srv)
        with pytest.raises(ValueError, match="compressor mismatch"):
            restore_server(str(tmp_path), _comp(16))

    def test_generation_fenced_restore(self, tmp_path):
        """Generation counters survive verbatim: a handle minted before
        the crash stays valid after restore, and a stale one still
        raises."""
        srv = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        chunks = _chunks(3)
        srv.admit(1)
        srv.close(1)
        srv.admit(1)  # generation bumped twice on this slot
        srv.submit(1, chunks[0])
        srv.tick()
        tier, local = srv._locate(1)
        gen = srv._tier_pool(tier).generation_of(local)
        save_server(str(tmp_path), srv.n_ticks, srv)
        srv2, _, _ = restore_server(str(tmp_path), _comp(0))
        pool2 = srv2._tier_pool(tier)
        pool2.slot_state(local, expect_generation=gen)  # still valid
        with pytest.raises(StaleSlotError):
            pool2.slot_state(local, expect_generation=gen - 1)

    def test_non_serve_checkpoint_refused(self, tmp_path):
        store.save(str(tmp_path), 1, {"w": np.zeros((3,))})
        with pytest.raises(ValueError, match="serve"):
            restore_server(str(tmp_path), _comp(0), step=1)

    def test_restore_falls_back_past_damaged_newest(self, tmp_path):
        srv = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        chunks = _chunks(2)
        srv.admit(1)
        srv.submit(1, chunks[0])
        srv.tick()
        save_server(str(tmp_path), 1, srv)
        srv.submit(1, chunks[1])
        srv.tick()
        save_server(str(tmp_path), 2, srv)
        # crash-truncated newest step: manifest survived, a shard didn't
        os.unlink(tmp_path / "step_00000002" / "shard_0.npz")
        srv2, _, step = restore_server(str(tmp_path), _comp(0))
        assert step == 1
        assert srv2.n_ticks == 1

    def test_snapshot_requires_matching_ingest(self, tmp_path):
        srv = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        other = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        with pytest.raises(ValueError, match="different StreamServer"):
            snapshot_server(srv, ingest=IngestServer(other))

    def test_wire_cursors_roundtrip(self, tmp_path):
        srv = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        ingest = IngestServer(srv, strict_seq=True)
        loop = Loopback(ingest)
        chunks = _chunks(11)
        assert loop.send(codec.encode_control(codec.OP_OPEN, 4)).ok
        for seq in range(2):
            assert loop.send(codec.encode_chunk(
                chunks[seq], stream_id=4, seq=seq, timestamp_ns=0,
            )).ok
            ingest.tick()
        save_server(str(tmp_path), srv.n_ticks, srv, ingest=ingest)
        _, ing2, _ = restore_server(
            str(tmp_path), _comp(0), with_ingest=True
        )
        assert ing2.strict_seq and ing2._seq_seen == {4: 1}
        assert ing2.counters()["n_frames_in"] == 2
        # the restored cursor refuses a replayed duplicate like the
        # original would
        reply = codec.decode_reply(ing2.handle_message(codec.encode_chunk(
            chunks[0], stream_id=4, seq=1, timestamp_ns=0,
        )))
        assert reply.status == codec.NACK_OUT_OF_ORDER


# ---------------------------------------------------------------------------
# Checkpointer cadence


class TestServeCheckpointer:
    def test_cadence_and_gc(self, tmp_path):
        srv = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        chunks = _chunks(9, n_frames=96)
        srv.admit(1)
        ckpt = ServeCheckpointer(
            str(tmp_path), srv, every_ticks=2, keep=2
        )
        saves = 0
        for i in range(7):
            srv.submit(1, chunks[i])
            srv.tick()
            saves += ckpt.maybe_save()
            assert not ckpt.maybe_save()  # idempotent within a tick
        ckpt.wait()
        assert saves == 3 and ckpt.n_saves == 3
        assert store.complete_steps(str(tmp_path)) == [4, 6]  # keep=2

    def test_every_ticks_validated(self, tmp_path):
        srv = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        with pytest.raises(ValueError, match="every_ticks"):
            ServeCheckpointer(str(tmp_path), srv, every_ticks=0)

    def test_restore_waits_for_inflight_save(self, tmp_path):
        srv = StreamServer(_comp(0), _server_cfg(k_ladder=None))
        srv.admit(1)
        srv.submit(1, _chunks(1)[0])
        srv.tick()
        ckpt = ServeCheckpointer(str(tmp_path), srv, every_ticks=1)
        ckpt.save_now()  # async write possibly still in flight
        srv2, _, step = ckpt.restore(_comp(0))
        assert step == 1 and srv2.live_sessions == [1]


# ---------------------------------------------------------------------------
# The crash/fault-injection soak


class _FlakyTransport:
    """Loopback wrapper that can die mid-wire-frame: before delivering
    a data frame it consults the injector with ``("wire", sid, seq)`` —
    a fired point crashes the 'process' with the frame unacked (it
    stays in the client's window for post-restore replay)."""

    def __init__(self, loop, injector):
        self.loop = loop
        self.inj = injector

    def send(self, msg):
        if self.inj is not None:
            kind, frame = codec.decode_message(msg)
            if kind == "data":
                self.inj.maybe_fail(("wire", frame.stream_id, frame.seq))
        return self.loop.send(msg)


def _run_reference():
    """The uninterrupted run: per-stream final states + k_trajectory."""
    return _run_soak(None, [], tiers=None)


def _run_soak(tmp_path, fail_at, *, tiers=None, damage_newest=False):
    """Drive N_STREAMS through N_ROUNDS of send+tick with checkpoints
    every 2 ticks; any injected WorkerFailure 'kills the process'
    (server, ingest, checkpointer all dropped on the floor), restores
    into fresh objects, RESUMEs every client session, and carries on.
    Returns per-stream final states, k trajectories, and the final
    server for extra assertions."""
    inj = fault.FailureInjector(fail_at)
    chunks = {sid: _chunks(sid) for sid in range(1, N_STREAMS + 1)}

    srv = StreamServer(_comp(8), _server_cfg(tiers=tiers))
    ingest = IngestServer(srv)
    ckpt = (
        ServeCheckpointer(str(tmp_path), srv, every_ticks=2, ingest=ingest)
        if tmp_path is not None
        else None
    )
    loop = Loopback(ingest)
    sess = {
        sid: ResumableSession(
            _FlakyTransport(loop, inj), sid, drain=ingest.tick
        )
        for sid in chunks
    }
    for s in sess.values():
        assert s.open().ok

    pos = {sid: 0 for sid in chunks}  # next chunk index per stream
    i = 0
    n_crashes = 0
    while i < N_ROUNDS:
        try:
            for sid, s in sess.items():
                if pos[sid] == i:
                    pos[sid] = i + 1
                    s.send_chunk(chunks[sid][i])
            inj.maybe_fail(("mid_tick", i))
            ingest.tick()
            if ckpt is not None:
                ckpt.maybe_save()
            inj.maybe_fail(("post_tick", i))
            i += 1
        except fault.WorkerFailure:
            assert ckpt is not None, "crash injected without a checkpointer"
            n_crashes += 1
            # -- the process dies here --------------------------------
            ckpt.wait()  # the dying writer's last save lands or not;
            if damage_newest:
                # simulate dying *mid-save* instead: the newest step is
                # a partial write (no manifest) plus tmp debris
                newest = store.latest_step(str(tmp_path))
                part = tmp_path / f"step_{newest + 1:08d}"
                part.mkdir()
                (part / "shard_0.npz").write_bytes(b"partial write")
                tmp = tmp_path / f"step_{newest + 2:08d}.tmp"
                tmp.mkdir()
                (tmp / "shard_0.npz").write_bytes(b"crashed")
            # -- a fresh process restores ------------------------------
            srv, ingest, _step = restore_server(
                str(tmp_path), _comp(8), with_ingest=True
            )
            ckpt = ServeCheckpointer(
                str(tmp_path), srv, every_ticks=2, ingest=ingest
            )
            loop = Loopback(ingest)
            for s in sess.values():
                s.transport = _FlakyTransport(loop, inj)
                s.drain = ingest.tick
                s.resume()  # replay everything past the restored cursor
    while any(len(q) for q in srv._queues.values()):
        ingest.tick()
    if ckpt is not None:
        # An async save may still be in flight; its step_*.tmp must not
        # be mistaken for crash debris by the cleanup assertions.
        ckpt.wait()
    states = {
        sid: jax.tree.map(np.asarray, srv.state(sid)) for sid in chunks
    }
    trajs = {
        sid: list(srv.telemetry(sid).k_trajectory) for sid in chunks
    }
    return states, trajs, srv, n_crashes


class TestCrashSoak:
    @pytest.fixture(scope="class")
    def reference(self):
        states, trajs, _, _ = _run_soak(None, [], tiers=None)
        return states, trajs

    @pytest.mark.parametrize(
        "fail_at,damage_newest",
        [
            ([("mid_tick", 2)], False),
            ([("post_tick", 2)], True),
            ([("wire", 2, 3)], False),
            ([("mid_tick", 2), ("wire", 3, 4)], False),
        ],
        ids=["mid_tick", "mid_save", "mid_wire_frame", "double_crash"],
    )
    def test_bit_exact_recovery(
        self, tmp_path, reference, fail_at, damage_newest
    ):
        ref_states, ref_trajs = reference
        states, trajs, srv, n_crashes = _run_soak(
            tmp_path, fail_at, damage_newest=damage_newest
        )
        assert n_crashes == len(fail_at)
        for sid in ref_states:
            _assert_tree_bitwise(
                ref_states[sid], states[sid], f"stream {sid}"
            )
            assert ref_trajs[sid] == trajs[sid], f"stream {sid}"
        # zero post-restore retraces: every variant compiled once in
        # the final (restored) process
        assert all(v == 1 for v in srv.step_cache_sizes().values())
        # mid-save debris never survives a later completed save
        assert not [
            n for n in os.listdir(tmp_path) if n.endswith(".tmp")
        ]

    def test_mid_migration_crash(self, tmp_path, reference):
        """Tiered pool: crash after a tick whose rebalance migrated a
        stream; restore re-binds the tiered placement verbatim and the
        run stays bitwise identical to the *flat* reference (the tiered
        == flat contract composes with crash/restore)."""
        ref_states, ref_trajs = reference
        states, trajs, srv, n_crashes = _run_soak(
            tmp_path, [("post_tick", 2)], tiers=(2, 2)
        )
        assert n_crashes == 1
        assert srv._tiered and srv.pool.n_migrations >= 1
        for sid in ref_states:
            _assert_tree_bitwise(
                ref_states[sid], states[sid], f"stream {sid}"
            )
            assert ref_trajs[sid] == trajs[sid], f"stream {sid}"
        assert all(v == 1 for v in srv.step_cache_sizes().values())
