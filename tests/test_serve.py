"""Serving-runtime tests (`repro.serve`): slotted admission/eviction
determinism, per-stream adaptive-K parity vs solo sessions, prefetch
ingest bit-identity, masked-slot isolation, the 2-device shard_map
path, and the long-running soak of the acceptance criteria (mixed
rungs + churn, bitwise vs solo, zero retraces after warmup)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro import serve
from repro.core import pipeline as P
from repro.data import synthetic as SYN
from repro.serve import (
    ChunkQueue,
    KLadderController,
    Prefetch,
    ServerConfig,
    SlottedPool,
    StreamServer,
)

FRAME = 64
PATCH = 16
CHUNK = 8

_SUB_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
for _k in ("JAX_PLATFORMS", "XLA_FLAGS", "HOME"):
    if _k in os.environ:
        _SUB_ENV[_k] = os.environ[_k]


def _ecfg(**kw):
    base = dict(
        frame_hw=(FRAME, FRAME), patch=PATCH, capacity=32,
        tau=0.10, gamma=0.015, theta=8, window=16,
    )
    base.update(kw)
    return P.EPICConfig(**base)


def _stream(seed, n_frames=16, n_obj=4):
    scfg = SYN.StreamConfig(n_frames=n_frames, hw=(FRAME, FRAME), n_obj=n_obj)
    return SYN.generate_stream(jax.random.PRNGKey(seed), scfg)[0]


def _chunks(s, n=CHUNK):
    for lo in range(0, s.frames.shape[0], n):
        yield api.SensorChunk(
            s.frames[lo:lo + n], s.poses[lo:lo + n],
            s.gazes[lo:lo + n], s.depth[lo:lo + n],
        )


def _solo_final_state(cfg, chunks, k_ladder=None):
    comp = api.EPICCompressor(cfg, k_ladder=k_ladder)
    step = comp.step if k_ladder is not None else jax.jit(comp.step)
    state = comp.init()
    for c in chunks:
        state, _ = step(state, c)
    return comp, state


def _assert_tree_bitwise(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{msg} leaf {i}"
        )


# ---------------------------------------------------------------------------
# SlottedPool: admission/eviction semantics
# ---------------------------------------------------------------------------


class TestSlottedPool:
    def test_admit_evict_bookkeeping(self):
        pool = SlottedPool(api.EPICCompressor(_ecfg(capacity=8)), 3)
        assert pool.free_slots() == [0, 1, 2]
        assert pool.admit("a") == 0
        assert pool.admit("b") == 1
        assert pool.n_active == 2
        assert bool(pool.states.active[0]) and bool(pool.states.active[1])
        assert pool.generation_of(0) == 1
        pool.evict_session("a")
        assert not bool(pool.states.active[0])
        assert pool.free_slots() == [0, 2]
        # re-admission into the same slot bumps the generation
        assert pool.admit("c", slot=0) == 0
        assert pool.generation_of(0) == 2
        with pytest.raises(ValueError, match="already admitted"):
            pool.admit("c")
        with pytest.raises(RuntimeError, match="pool full"):
            pool.admit("d"), pool.admit("e")
        with pytest.raises(KeyError, match="not admitted"):
            pool.slot_of("zz")

    def test_adaptive_compressor_rejected(self):
        comp = api.EPICCompressor(
            _ecfg(prefilter_k=4), k_ladder=(4, 8)
        )
        with pytest.raises(ValueError, match="StreamServer"):
            SlottedPool(comp, 2)

    def test_masked_step_equals_sessions_and_isolation(self):
        """Active slots step bit-identically to solo sessions; inactive
        slots' state is untouched by any number of pool steps."""
        streams = [_stream(10 + i) for i in range(3)]
        cfg = _ecfg(capacity=16)
        pool = SlottedPool(api.EPICCompressor(cfg), 4)
        for i in range(3):
            pool.admit(i)
        frozen_idle = jax.tree.map(
            lambda x: np.asarray(x[3]), pool.states.sessions
        )
        zero = jax.tree.map(jnp.zeros_like, next(_chunks(streams[0])))
        for step_i in range(2):
            rows = [
                list(_chunks(s))[step_i] for s in streams
            ] + [zero]
            batch = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
            stats = pool.step(batch)
        # inactive slot 3: bit-identical to its pre-serving bytes
        idle_now = jax.tree.map(lambda x: x[3], pool.states.sessions)
        _assert_tree_bitwise(idle_now, frozen_idle, "idle slot")
        # stats on the inactive slot are zeroed
        assert int(jnp.sum(stats.processed[3])) == 0
        # active slots: solo parity
        for i, s in enumerate(streams):
            _, ref = _solo_final_state(cfg, _chunks(s))
            _assert_tree_bitwise(
                pool.session_state(i), ref, f"stream {i}"
            )

    def test_evict_readmit_is_fresh_session_bitwise(self):
        """Evicting a slot and re-admitting into it == a fresh session:
        the leftover state bytes of the previous tenant are dead."""
        s_old, s_new = _stream(1), _stream(2)
        cfg = _ecfg(capacity=16)
        pool = SlottedPool(api.EPICCompressor(cfg), 2)
        pool.admit("old", slot=0)
        pool.admit("other", slot=1)
        zero = jax.tree.map(jnp.zeros_like, next(_chunks(s_old)))
        for c in _chunks(s_old):
            batch = jax.tree.map(lambda *xs: jnp.stack(xs), c, zero)
            pool.step(batch)
        pool.evict(0)
        pool.admit("new", slot=0)
        for c in _chunks(s_new):
            batch = jax.tree.map(lambda *xs: jnp.stack(xs), c, zero)
            pool.step(batch)
        _, ref = _solo_final_state(cfg, _chunks(s_new))
        _assert_tree_bitwise(
            pool.session_state("new"), ref, "readmitted slot"
        )

    def test_mask_cannot_step_evicted_slot(self):
        cfg = _ecfg(capacity=16)
        pool = SlottedPool(api.EPICCompressor(cfg), 2)
        pool.admit("a", slot=0)
        chunk = next(_chunks(_stream(3)))
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), chunk, chunk)
        before = jax.tree.map(
            lambda x: np.asarray(x[1]), pool.states.sessions
        )
        # slot 1 was never admitted: an all-true mask must not touch it
        pool.step(batch, mask=jnp.ones((2,), bool))
        after = jax.tree.map(lambda x: x[1], pool.states.sessions)
        _assert_tree_bitwise(after, before, "never-admitted slot")

    def test_step_shape_validation(self):
        pool = SlottedPool(api.EPICCompressor(_ecfg(capacity=8)), 2)
        chunk = next(_chunks(_stream(0)))
        with pytest.raises(ValueError, match="leading slot axis"):
            pool.step(chunk)

    def test_readmission_generation_fences_stale_handles(self):
        """A stale session id (or a cached ``(slot, generation)``
        handle) must never read the slot's *new* occupant."""
        from repro.serve import StaleSlotError

        pool = SlottedPool(api.EPICCompressor(_ecfg(capacity=8)), 2)
        pool.admit("old", slot=0)
        handle = (0, pool.generation_of(0))
        pool.evict(0)
        pool.admit("new", slot=0)
        # the stale session id is simply gone
        with pytest.raises(KeyError, match="not admitted"):
            pool.session_state("old")
        # the stale (slot, generation) handle is fenced...
        with pytest.raises(StaleSlotError, match="re-admitted"):
            pool.slot_state(handle[0], expect_generation=handle[1])
        # ...and StaleSlotError is a KeyError (one except clause for
        # "session gone" at the wire layer)
        assert issubclass(StaleSlotError, KeyError)
        # a current handle still reads fine
        pool.slot_state(0, expect_generation=pool.generation_of(0))

    def test_speculative_admission_inits_once(self):
        """``compressor.init()`` runs once per pool — every admit is a
        device-side copy of the cached fresh image."""
        comp = api.EPICCompressor(_ecfg(capacity=8))
        calls = []
        real_init = comp.init

        class Counting:
            def __getattr__(self, name):
                return getattr(comp, name)

            def init(self):
                calls.append(1)
                return real_init()

        pool = SlottedPool(Counting(), 3)
        pool.prewarm()
        for churn in range(3):
            pool.admit(f"s{churn}")
            pool.evict_session(f"s{churn}")
        assert len(calls) == 1
        # prewarm leaves every slot free, only generations advanced
        assert pool.free_slots() == [0, 1, 2]
        assert int(pool._admit_fn._cache_size()) == 1

    def test_no_retrace_across_churn(self):
        """admit/evict/step each compile exactly once, regardless of
        which slots churn."""
        cfg = _ecfg(capacity=16)
        pool = SlottedPool(api.EPICCompressor(cfg), 3)
        chunk = next(_chunks(_stream(4)))
        batch = jax.tree.map(
            lambda *xs: jnp.stack(xs), chunk, chunk, chunk
        )
        pool.admit("a")
        pool.step(batch)
        pool.admit("b")
        pool.step(batch)
        pool.evict_session("a")
        pool.admit("c")
        pool.step(batch)
        assert pool.step_cache_sizes() == {None: 1}
        assert int(pool._admit_fn._cache_size()) == 1
        assert int(pool._evict_fn._cache_size()) == 1


# ---------------------------------------------------------------------------
# KLadderController (extracted controller) + EPICCompressor compatibility
# ---------------------------------------------------------------------------


class TestKLadderController:
    def test_walk(self):
        ctl = KLadderController((4, 8, 16), start_k=0)
        assert ctl.k == 4
        assert ctl.begin_chunk() == 4
        assert ctl.update(overflow=1, peak_full=4) == 8  # grow
        assert ctl.update(overflow=1, peak_full=8) == 16  # grow
        assert ctl.update(overflow=1, peak_full=16) == 16  # top rung
        assert ctl.update(overflow=0, peak_full=3) == 8  # 3*2 <= 8
        assert ctl.update(overflow=0, peak_full=3) == 8  # 3*2 > 4
        assert ctl.k_trajectory == [4]

    def test_validation(self):
        with pytest.raises(ValueError, match="not a rung"):
            KLadderController((4, 8), start_k=5)
        with pytest.raises(ValueError, match="strictly increasing"):
            KLadderController((8, 4))
        with pytest.raises(ValueError, match="shrink_margin"):
            KLadderController((4, 8), shrink_margin=0)

    def test_compressor_uses_extracted_controller(self):
        comp = api.EPICCompressor(
            _ecfg(prefilter_k=8), k_ladder=(4, 8, 16)
        )
        assert isinstance(comp._ctl, KLadderController)
        assert comp.k_ladder == (4, 8, 16)
        assert comp.k_trajectory is comp._ctl.k_trajectory


# ---------------------------------------------------------------------------
# Prefetch ingest + ChunkQueue
# ---------------------------------------------------------------------------


class TestIngest:
    def test_prefetch_bit_identical_to_sync(self):
        s = _stream(7, n_frames=32)
        cfg = _ecfg(capacity=16)
        _, ref = _solo_final_state(cfg, _chunks(s))
        comp = api.EPICCompressor(cfg)
        step = jax.jit(comp.step)
        state = comp.init()
        n = 0
        for c in Prefetch(_chunks(s), depth=2):
            state, _ = step(state, c)
            n += 1
        assert n == 4
        _assert_tree_bitwise(state, ref, "prefetched session")

    def test_prefetch_registered_combinator(self):
        assert set(api.available_combinators()) >= {"gated", "prefetch"}
        pf = api.make_combinator("prefetch", [1, 2, 3])
        assert isinstance(pf, Prefetch)
        assert [int(jax.device_get(x)) for x in pf] == [1, 2, 3]
        with pytest.raises(KeyError, match="unknown combinator"):
            api.get_combinator("zipline")

    def test_prefetch_depth_validation(self):
        with pytest.raises(ValueError, match="depth"):
            Prefetch([], depth=0)

    def test_chunk_queue_backpressure(self):
        q = ChunkQueue(maxlen=2)
        assert q.push("c0") and q.push("c1")
        assert not q.push("c2")
        assert q.n_overflow == 1 and q.n_pushed == 2
        assert q.pop() == "c0"
        assert q.push("c2")
        assert [q.pop(), q.pop(), q.pop()] == ["c1", "c2", None]


# ---------------------------------------------------------------------------
# StreamServer: policies, backpressure, telemetry
# ---------------------------------------------------------------------------


class TestStreamServer:
    def _server(self, capacity=2, **kw):
        cfgkw = dict(capacity=capacity, chunk_frames=CHUNK)
        cfgkw.update(kw)
        return StreamServer(
            api.EPICCompressor(_ecfg(capacity=16)), ServerConfig(**cfgkw)
        )

    def test_validation(self):
        comp = api.EPICCompressor(_ecfg(capacity=16))
        with pytest.raises(ValueError, match="eviction policy"):
            StreamServer(comp, ServerConfig(eviction="random"))
        with pytest.raises(ValueError, match="ServerConfig.k_ladder"):
            StreamServer(
                api.EPICCompressor(_ecfg(prefilter_k=4), k_ladder=(4, 8)),
                ServerConfig(),
            )
        with pytest.raises(ValueError, match="prefilter_k"):
            StreamServer(
                api.get_compressor("fv")(api.BaselineConfig()),
                ServerConfig(k_ladder=(4, 8)),
            )
        # a start K off the ladder fails at construction, not at the
        # first admit (which would leave a half-admitted slot behind)
        with pytest.raises(ValueError, match="not a rung"):
            StreamServer(
                api.EPICCompressor(_ecfg(prefilter_k=24)),
                ServerConfig(k_ladder=(4, 8)),
            )
        with pytest.raises(ValueError, match="shrink_margin"):
            StreamServer(
                api.EPICCompressor(_ecfg(prefilter_k=4)),
                ServerConfig(k_ladder=(4, 8), shrink_margin=0),
            )

    def test_full_pool_rejects_then_lru_evicts(self):
        srv = self._server(capacity=2)
        srv.admit("a"), srv.admit("b")
        with pytest.raises(RuntimeError, match="pool full"):
            srv.admit("c")
        assert srv.try_admit("c") is None
        assert srv.n_admit_rejected == 2

        lru = self._server(capacity=2, eviction="lru")
        lru.admit("a"), lru.admit("b")
        # a duplicate admit must not evict an innocent LRU victim
        with pytest.raises(ValueError, match="already admitted"):
            lru.admit("a")
        assert set(lru.live_sessions) == {"a", "b"}
        c0 = next(_chunks(_stream(0)))
        lru.submit("b", c0)
        lru.tick()  # "b" stepped; "a" never stepped -> LRU victim
        lru.admit("c")
        assert set(lru.live_sessions) == {"b", "c"}
        assert lru.n_evicted == 1
        assert lru.evicted[0].session_id == "a"

    def test_lru_eviction_tie_breaks_on_slot(self):
        """Streams that are LRU-equal (same last-stepped tick —
        including never-stepped) evict deterministically: lowest slot
        first."""
        lru = self._server(capacity=3, eviction="lru")
        for sid in ("a", "b", "c"):
            lru.admit(sid)
        # never stepped: all tie at last_step_tick == -1 -> slot order
        lru.admit("d")
        assert lru.evicted[0].session_id == "a"
        # step the two original survivors in one tick: they tie again
        c0 = next(_chunks(_stream(0)))
        lru.submit("b", c0), lru.submit("c", c0)
        lru.tick()
        lru.admit("e")  # "d" never stepped -> strict LRU, no tie
        assert lru.evicted[1].session_id == "d"
        lru.submit("e", c0)
        lru.tick()  # "e" now fresher than the tied "b"/"c"
        lru.admit("f")  # "b" (slot 1) vs "c" (slot 2): tie -> "b"
        assert lru.evicted[2].session_id == "b"

    def test_submit_validates_quantum_and_backpressure(self):
        srv = self._server(capacity=1, queue_depth=1)
        srv.admit("a")
        s = _stream(0)
        with pytest.raises(ValueError, match="quantum"):
            srv.submit("a", api.SensorChunk(
                s.frames[:4], s.poses[:4], s.gazes[:4], s.depth[:4]
            ))
        with pytest.raises(KeyError, match="not admitted"):
            srv.submit("ghost", next(_chunks(s)))
        assert srv.submit("a", next(_chunks(s)))
        assert not srv.submit("a", next(_chunks(s)))  # queue full
        assert srv.n_backpressure == 1
        assert srv.telemetry("a").n_queue_overflow == 1

    def test_idle_eviction(self):
        srv = self._server(capacity=2, eviction="idle",
                           idle_frames=2 * CHUNK)
        srv.admit("busy"), srv.admit("lazy")
        chunks = list(_chunks(_stream(0), CHUNK)) * 2
        for c in chunks[:3]:
            srv.submit("busy", c)
            srv.tick()
        assert srv.live_sessions == ["busy"]
        assert srv.evicted and srv.evicted[0].session_id == "lazy"
        # the evicted stream's telemetry survives
        assert srv.evicted[0].idle_frames >= 2 * CHUNK

    def test_telemetry_counters(self):
        srv = self._server(capacity=1)
        srv.admit("a")
        for c in _chunks(_stream(5)):
            srv.submit("a", c)
            srv.tick()
        tele = srv.telemetry("a")
        assert tele.n_chunks == 2 and tele.n_frames == 16
        assert tele.n_processed >= 1
        assert tele.buffer_valid > 0
        c = srv.server_counters()
        assert c["frames_served"] == 16 and c["n_ticks"] == 2

    def test_drain_matches_submit_tick(self):
        s = _stream(9, n_frames=32)
        cfg = _ecfg(capacity=16)
        a = StreamServer(
            api.EPICCompressor(cfg),
            ServerConfig(capacity=2, chunk_frames=CHUNK),
        )
        a.admit("x")
        for c in _chunks(s):
            a.submit("x", c)
            a.tick()
        b = StreamServer(
            api.EPICCompressor(cfg),
            ServerConfig(capacity=2, chunk_frames=CHUNK),
        )
        b.drain({"x": Prefetch(_chunks(s))})
        _assert_tree_bitwise(a.state("x"), b.state("x"), "drain vs ticks")

    def test_export_and_tokens(self):
        from repro.core import packing
        from repro.core import retained as ret

        srv = self._server(capacity=1)
        srv.admit("a")
        srv.submit("a", next(_chunks(_stream(5))))
        srv.tick()
        assert isinstance(srv.export("a"), ret.RetainedPatches)
        assert srv.tokens("a", 16).tokens.shape == (
            16, packing.TOKEN_FEAT
        )


# ---------------------------------------------------------------------------
# Per-stream adaptive K over the pool == solo adaptive sessions
# ---------------------------------------------------------------------------


class TestPerStreamAdaptiveK:
    LADDER = (4, 8, 16, 48)

    def test_mixed_rungs_parity(self):
        """Streams of different complexity settle on different rungs,
        yet every per-stream state and k_trajectory is bitwise the solo
        adaptive session."""
        cfg = _ecfg(capacity=48, prefilter_k=4)
        streams = {
            "calm": _stream(20, n_frames=32, n_obj=1),
            "busy": _stream(21, n_frames=32, n_obj=6),
            "mid": _stream(22, n_frames=32, n_obj=3),
        }
        srv = StreamServer(
            api.EPICCompressor(cfg),
            ServerConfig(capacity=4, chunk_frames=CHUNK,
                         k_ladder=self.LADDER),
        )
        srv.drain({sid: _chunks(s) for sid, s in streams.items()})
        rungs_seen = set()
        for sid, s in streams.items():
            solo, ref = _solo_final_state(
                cfg, _chunks(s), k_ladder=self.LADDER
            )
            assert srv.telemetry(sid).k_trajectory == solo.k_trajectory, sid
            _assert_tree_bitwise(srv.state(sid), ref, sid)
            rungs_seen.update(solo.k_trajectory)
        # the scenario genuinely exercises bucketed dispatch
        assert len(rungs_seen) >= 2
        assert set(srv.pool.step_cache_sizes()) == rungs_seen

    def test_one_compile_per_rung(self):
        cfg = _ecfg(capacity=48, prefilter_k=4)
        srv = StreamServer(
            api.EPICCompressor(cfg),
            ServerConfig(capacity=2, chunk_frames=CHUNK,
                         k_ladder=self.LADDER),
        )
        srv.drain({
            "a": _chunks(_stream(23, n_frames=32, n_obj=5)),
            "b": _chunks(_stream(24, n_frames=32, n_obj=5)),
        })
        sizes = srv.pool.step_cache_sizes()
        assert sizes and all(v == 1 for v in sizes.values()), sizes


# ---------------------------------------------------------------------------
# Acceptance soak: churn + mixed rungs, bitwise vs solo, zero retraces
# ---------------------------------------------------------------------------


class TestSoak:
    def test_soak_churn_parity_and_no_retrace(self):
        """>= 200 frames through a pool of 8 with >= 3 evictions and
        >= 3 admissions and mixed adaptive-K rungs: every session's
        final state is bitwise the solo adaptive run over exactly the
        chunks it was served, and after each rung's first compile the
        jit caches never grow again."""
        cfg = _ecfg(capacity=48, prefilter_k=4)
        ladder = (4, 8, 16)
        srv = StreamServer(
            api.EPICCompressor(cfg),
            ServerConfig(capacity=8, chunk_frames=CHUNK, k_ladder=ladder),
        )
        # Scripted population: 6 founders of varying complexity, then a
        # churn wave (3 closures + 3 re-admissions into freed slots).
        def feed(seed, n_obj, n_frames):
            return list(_chunks(_stream(seed, n_frames=n_frames,
                                        n_obj=n_obj)))

        founders = {
            f"s{i}": feed(30 + i, n_obj=1 + (i % 3) * 2, n_frames=32)
            for i in range(6)
        }
        late = {
            f"l{i}": feed(40 + i, n_obj=2 + i, n_frames=24)
            for i in range(3)
        }
        served = {sid: [] for sid in list(founders) + list(late)}

        def serve_tick(submissions):
            for sid, chunk in submissions:
                srv.submit(sid, chunk)
                served[sid].append(chunk)
            srv.tick()

        for sid in founders:
            srv.admit(sid)
        # phase 1: founders stream 2 chunks each (warmup visits rungs)
        for step_i in range(2):
            serve_tick(
                (sid, chunks[step_i]) for sid, chunks in founders.items()
            )
        warm_sizes = dict(srv.pool.step_cache_sizes())
        # phase 2: churn — close 3 founders, admit 3 late joiners into
        # the freed slots; survivors keep streaming where they left off
        for sid in ("s0", "s2", "s4"):
            srv.close(sid)
        for sid in late:
            srv.admit(sid)
        for step_i in range(2):
            serve_tick(
                [(sid, founders[sid][2 + step_i])
                 for sid in ("s1", "s3", "s5")]
                + [(sid, chunks[step_i]) for sid, chunks in late.items()]
            )
        # phase 3: only the late joiners still have data (ragged tail)
        serve_tick((sid, chunks[2]) for sid, chunks in late.items())

        assert srv.n_evicted >= 3 and srv.n_admitted >= 9
        assert srv.frames_served >= 200, srv.frames_served
        # mixed rungs were genuinely in play
        assert len(srv.pool.step_cache_sizes()) >= 2
        # zero retraces after warmup: every rung visited during warmup
        # still holds exactly one compiled trace, and rungs first
        # visited later also compiled exactly once
        end_sizes = srv.pool.step_cache_sizes()
        for k, n in end_sizes.items():
            assert n == 1, (k, end_sizes)
        for k, n in warm_sizes.items():
            assert end_sizes[k] == n, (warm_sizes, end_sizes)
        assert int(srv.pool._admit_fn._cache_size()) == 1
        assert int(srv.pool._evict_fn._cache_size()) == 1

        # bitwise parity: live sessions vs solo adaptive replays of
        # exactly the chunks each was served
        for sid in srv.live_sessions:
            solo, ref = _solo_final_state(
                cfg, served[sid], k_ladder=ladder
            )
            assert srv.telemetry(sid).k_trajectory == solo.k_trajectory
            _assert_tree_bitwise(srv.state(sid), ref, sid)


# ---------------------------------------------------------------------------
# Telemetry: batched pool counters == per-stream loop, one device_get
# ---------------------------------------------------------------------------


class TestTelemetryCounters:
    def test_pool_stream_counters_matches_per_stream(self, monkeypatch):
        streams = [_stream(50 + i) for i in range(3)]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *streams)
        cfg = _ecfg(capacity=16)
        pool = api.StreamPool(api.EPICCompressor(cfg), 3)
        _, stats = pool.step(pool.init(), api.SensorChunk(
            batch.frames, batch.poses, batch.gazes, batch.depth
        ))
        expect = [
            P.stream_counters(cfg, jax.tree.map(lambda x: x[i], stats))
            for i in range(3)
        ]
        calls = []
        real = jax.device_get
        monkeypatch.setattr(
            jax, "device_get", lambda x: calls.append(1) or real(x)
        )
        got = serve.pool_stream_counters(cfg, stats)
        monkeypatch.undo()
        assert len(calls) == 1  # the whole pool in one host sync
        assert got == expect
        sub = serve.pool_stream_counters(cfg, stats, streams=[2])
        assert sub == [expect[2]]


# ---------------------------------------------------------------------------
# shard_map serving path (2 forced host devices, subprocess)
# ---------------------------------------------------------------------------


class TestShardedServe:
    def test_two_device_server_matches_single(self):
        prog = """
import jax, jax.numpy as jnp, numpy as np
from repro import api
from repro.core import pipeline as P
from repro.data import synthetic as SYN
from repro.launch.mesh import make_stream_mesh
from repro.serve import ServerConfig, StreamServer

assert len(jax.devices()) == 2, jax.devices()
cfg = P.EPICConfig(frame_hw=(64, 64), patch=16, capacity=12,
                   tau=0.10, gamma=0.015, theta=8, window=16,
                   prefilter_k=4)
scfg = SYN.StreamConfig(n_frames=16, hw=(64, 64), n_obj=3)
streams = {i: SYN.generate_stream(jax.random.PRNGKey(i), scfg)[0]
           for i in range(3)}

def chunks(s, n=8):
    for lo in range(0, 16, n):
        yield api.SensorChunk(s.frames[lo:lo+n], s.poses[lo:lo+n],
                              s.gazes[lo:lo+n], s.depth[lo:lo+n])

def run(mesh):
    srv = StreamServer(
        api.EPICCompressor(cfg),
        ServerConfig(capacity=4, chunk_frames=8, k_ladder=(4, 8, 16)),
        mesh=mesh, donate=False,
    )
    for i in streams:
        srv.admit(i)
    for step_i in range(2):
        for i, s in streams.items():
            srv.submit(i, list(chunks(s))[step_i])
        srv.tick()
    # churn on the live sharded pool
    srv.close(1)
    srv.admit("fresh")
    srv.submit("fresh", next(chunks(streams[1])))
    srv.tick()
    return srv

sharded = run(make_stream_mesh())
local = run(None)
for sid in (0, 2, "fresh"):
    for a, b in zip(jax.tree.leaves(sharded.state(sid)),
                    jax.tree.leaves(local.state(sid))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (sharded.telemetry(sid).k_trajectory
            == local.telemetry(sid).k_trajectory)
try:
    StreamServer(api.EPICCompressor(cfg),
                 ServerConfig(capacity=3, chunk_frames=8),
                 mesh=make_stream_mesh())
except ValueError as e:
    assert "divide evenly" in str(e), e
else:
    raise AssertionError("expected divisibility ValueError")
print("SHARDED_SERVE_OK")
"""
        env = dict(_SUB_ENV)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=2"
        ).strip()
        r = subprocess.run(
            [sys.executable, "-c", prog],
            capture_output=True, text=True, timeout=500, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert r.returncode == 0, r.stderr[-2000:]
        assert "SHARDED_SERVE_OK" in r.stdout


# ---------------------------------------------------------------------------
# launch/serve deprecation shim
# ---------------------------------------------------------------------------


def test_launch_serve_shim_reexports():
    from repro.launch import serve as legacy
    from repro.serve import efm

    assert legacy.greedy_decode_loop is efm.greedy_decode_loop
    assert legacy.jit_prefill is efm.jit_prefill
    assert legacy.jit_decode_step is efm.jit_decode_step
